//! Analytic eigenpairs of symmetric 2x2 matrices.
//!
//! The paper's lower-bound constructions (Thm 3, Thm 5 / Lemmas 8–9) are
//! all two-dimensional and its appendix repeatedly uses the closed-form
//! leading eigenvector of `[[a, b], [b, c]]` (reference \[1\] in the paper).
//! Implementing the closed form exactly as the appendix writes it lets the
//! lower-bound experiments and their tests mirror the proofs line by line.

/// Leading eigenvalue of `[[a, b], [b, c]]`.
pub fn lambda1_2x2(a: f64, b: f64, c: f64) -> f64 {
    let mean = 0.5 * (a + c);
    let disc = (0.25 * (a - c) * (a - c) + b * b).sqrt();
    mean + disc
}

/// Eigengap `lambda_1 - lambda_2` of `[[a, b], [b, c]]`.
pub fn gap_2x2(a: f64, b: f64, c: f64) -> f64 {
    2.0 * (0.25 * (a - c) * (a - c) + b * b).sqrt()
}

/// Leading **unit** eigenvector of `[[a, b], [b, c]]`, in the form used in
/// the proofs of Thm 3 / Lemma 8: proportional to
/// `((a - c)/2 + sqrt(((a - c)/2)^2 + b^2), b)`, which always has a
/// non-negative first component (the "sign-fixed to e1" representative).
///
/// For `b == 0` and `a >= c` this returns `e1`; for `b == 0, a < c` it
/// returns `e2`.
pub fn leading_eigvec_2x2(a: f64, b: f64, c: f64) -> [f64; 2] {
    if b == 0.0 {
        // decoupled axes: the formula's first component degenerates to 0
        // when a < c, so handle the diagonal case explicitly.
        return if a >= c { [1.0, 0.0] } else { [0.0, 1.0] };
    }
    let half = 0.5 * (a - c);
    let disc = (half * half + b * b).sqrt();
    let u = [half + disc, b];
    let n = (u[0] * u[0] + u[1] * u[1]).sqrt();
    if n == 0.0 {
        // a == c and b == 0: degenerate (any vector); pick e1 — callers in
        // the lower-bound experiments treat this as measure-zero.
        return [1.0, 0.0];
    }
    [u[0] / n, u[1] / n]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matrix::Matrix;
    use crate::linalg::SymEigen;
    use crate::rng::Pcg64;

    #[test]
    fn diagonal_cases() {
        assert_eq!(leading_eigvec_2x2(2.0, 0.0, 1.0), [1.0, 0.0]);
        let v = leading_eigvec_2x2(1.0, 0.0, 2.0);
        assert!(v[0].abs() < 1e-15 && (v[1].abs() - 1.0).abs() < 1e-15);
        assert_eq!(lambda1_2x2(2.0, 0.0, 1.0), 2.0);
        assert_eq!(gap_2x2(2.0, 0.0, 1.0), 1.0);
    }

    #[test]
    fn matches_general_solver() {
        let mut rng = Pcg64::new(77);
        for _ in 0..200 {
            let a = rng.next_f64() * 4.0 - 2.0;
            let b = rng.next_f64() * 4.0 - 2.0;
            let c = rng.next_f64() * 4.0 - 2.0;
            let m = Matrix::from_vec(2, 2, vec![a, b, b, c]);
            let e = SymEigen::new(&m);
            assert!((e.lambda1() - lambda1_2x2(a, b, c)).abs() < 1e-10);
            assert!((e.eigengap() - gap_2x2(a, b, c)).abs() < 1e-10);
            let v = leading_eigvec_2x2(a, b, c);
            let w = e.leading();
            let align = (v[0] * w[0] + v[1] * w[1]).abs();
            assert!(align > 1.0 - 1e-9, "align={align} for ({a},{b},{c})");
        }
    }

    #[test]
    fn paper_thm3_matrix_shape() {
        // Xhat = [[2, y], [y, 1]]: eigvec formula from the Thm 3 proof is
        // proportional to (1, 2y/(1 + sqrt(1+4y^2)))
        for &y in &[0.3, -0.2, 0.05, 0.9] {
            let v = leading_eigvec_2x2(2.0, y, 1.0);
            let t = 2.0 * y / (1.0 + (1.0f64 + 4.0 * y * y).sqrt());
            let expect_ratio = t;
            assert!((v[1] / v[0] - expect_ratio).abs() < 1e-12);
        }
    }

    #[test]
    fn sign_fixed_first_component_nonneg() {
        let mut rng = Pcg64::new(78);
        for _ in 0..100 {
            let a = rng.next_f64();
            let b = rng.next_f64() - 0.5;
            let c = rng.next_f64() - 1.0; // ensure a usually > c
            let v = leading_eigvec_2x2(a, b, c);
            assert!(v[0] >= 0.0);
        }
    }
}
