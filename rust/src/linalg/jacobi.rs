//! Cyclic Jacobi eigensolver.
//!
//! Kept as an *independent* oracle to cross-check the tred2/tqli solver in
//! [`crate::linalg::eigen`]: the two implementations share no code, so a
//! bug in either shows up as a disagreement in the cross-check tests.
//! Jacobi is also the more accurate choice for tiny matrices (it drives
//! the 2x2 sanity tests of the lower-bound constructions).

use super::matrix::Matrix;

/// Full eigendecomposition of a symmetric matrix via cyclic Jacobi
/// rotations. Returns `(values_desc, vectors)` where `vectors.col(k)` is
/// the unit eigenvector for `values_desc[k]`.
pub fn jacobi_eigen(a: &Matrix) -> (Vec<f64>, Matrix) {
    assert!(a.is_square(), "jacobi_eigen: matrix must be square");
    let n = a.rows();
    let mut m = a.clone();
    m.symmetrize();
    let mut v = Matrix::identity(n);

    let max_sweeps = 64;
    for _sweep in 0..max_sweeps {
        // off-diagonal Frobenius mass
        let mut off = 0.0;
        for i in 0..n {
            for j in (i + 1)..n {
                off += m.get(i, j) * m.get(i, j);
            }
        }
        if off.sqrt() < 1e-14 * (1.0 + m.max_abs()) {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m.get(p, q);
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = m.get(p, p);
                let aqq = m.get(q, q);
                // rotation angle zeroing (p,q)
                let theta = (aqq - app) / (2.0 * apq);
                let t = if theta >= 0.0 {
                    1.0 / (theta + (1.0 + theta * theta).sqrt())
                } else {
                    -1.0 / (-theta + (1.0 + theta * theta).sqrt())
                };
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = t * c;
                // apply rotation: rows/cols p and q
                for k in 0..n {
                    let mkp = m.get(k, p);
                    let mkq = m.get(k, q);
                    m.set(k, p, c * mkp - s * mkq);
                    m.set(k, q, s * mkp + c * mkq);
                }
                for k in 0..n {
                    let mpk = m.get(p, k);
                    let mqk = m.get(q, k);
                    m.set(p, k, c * mpk - s * mqk);
                    m.set(q, k, s * mpk + c * mqk);
                }
                for k in 0..n {
                    let vkp = v.get(k, p);
                    let vkq = v.get(k, q);
                    v.set(k, p, c * vkp - s * vkq);
                    v.set(k, q, s * vkp + c * vkq);
                }
            }
        }
    }

    let mut idx: Vec<usize> = (0..n).collect();
    let diag: Vec<f64> = (0..n).map(|i| m.get(i, i)).collect();
    idx.sort_by(|&i, &j| diag[j].partial_cmp(&diag[i]).unwrap());
    let values: Vec<f64> = idx.iter().map(|&i| diag[i]).collect();
    let mut vectors = Matrix::zeros(n, n);
    for (newc, &oldc) in idx.iter().enumerate() {
        vectors.set_col(newc, &v.col(oldc));
    }
    (values, vectors)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    #[test]
    fn jacobi_diag() {
        let a = Matrix::diag(&[5.0, 1.0, 3.0]);
        let (vals, _) = jacobi_eigen(&a);
        assert!((vals[0] - 5.0).abs() < 1e-12);
        assert!((vals[1] - 3.0).abs() < 1e-12);
        assert!((vals[2] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn jacobi_reconstructs() {
        let mut rng = Pcg64::new(55);
        let n = 10;
        let mut a = Matrix::zeros(n, n);
        for i in 0..n {
            for j in i..n {
                let x = rng.next_f64() - 0.5;
                a.set(i, j, x);
                a.set(j, i, x);
            }
        }
        let (vals, vecs) = jacobi_eigen(&a);
        let rec = vecs.matmul(&Matrix::diag(&vals)).matmul(&vecs.transpose());
        assert!(rec.sub(&a).max_abs() < 1e-10);
    }

    #[test]
    fn jacobi_orthonormal_vectors() {
        let mut rng = Pcg64::new(56);
        let n = 8;
        let mut a = Matrix::zeros(n, n);
        for i in 0..n {
            for j in i..n {
                let x = rng.next_f64();
                a.set(i, j, x);
                a.set(j, i, x);
            }
        }
        let (_, vecs) = jacobi_eigen(&a);
        let vtv = vecs.transpose().matmul(&vecs);
        assert!(vtv.sub(&Matrix::identity(n)).max_abs() < 1e-10);
    }
}
