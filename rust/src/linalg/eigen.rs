//! Symmetric eigensolver: Householder tridiagonalization followed by the
//! implicit-shift QL iteration, with eigenvector accumulation.
//!
//! This is the workhorse behind
//! - every machine's local ERM solution (leading eigenvector of `Xhat_i`),
//! - the centralized ERM baseline,
//! - the `C^{-1/2}` / `C^{-1}` preconditioner of Lemma 6 (via [`SymEigen::apply_fn`]),
//! - the projection-averaging estimator of §5.
//!
//! The implementation follows the classical `tred2` / `tqli` pair
//! (Householder, then QL with Wilkinson shifts); cost is `O(d^3)` with a
//! small constant, fine for the paper's `d = 300` regime. Correctness is
//! cross-checked against the independent cyclic-Jacobi solver in
//! [`crate::linalg::jacobi`].

use super::matrix::Matrix;

/// Eigendecomposition of a real symmetric matrix: `A = V diag(values) V^T`.
///
/// `values` are sorted **descending** (so `values[0] = lambda_1`, matching
/// the paper's notation) and `vectors.col(k)` is the unit eigenvector for
/// `values[k]`.
#[derive(Clone, Debug)]
pub struct SymEigen {
    values: Vec<f64>,
    vectors: Matrix,
}

/// `sqrt(a^2 + b^2)` without destructive overflow/underflow.
#[inline]
fn pythag(a: f64, b: f64) -> f64 {
    let (absa, absb) = (a.abs(), b.abs());
    if absa > absb {
        let r = absb / absa;
        absa * (1.0 + r * r).sqrt()
    } else if absb == 0.0 {
        0.0
    } else {
        let r = absa / absb;
        absb * (1.0 + r * r).sqrt()
    }
}

/// Householder reduction of a symmetric matrix to tridiagonal form.
/// On exit `a` holds the accumulated orthogonal transform `Q`, `d` the
/// diagonal and `e[1..]` the sub-diagonal.
fn tred2(a: &mut Matrix, d: &mut [f64], e: &mut [f64]) {
    let n = a.rows();
    for i in (1..n).rev() {
        let l = i - 1;
        let mut h = 0.0;
        if l > 0 {
            let mut scale = 0.0;
            for k in 0..=l {
                scale += a.get(i, k).abs();
            }
            if scale == 0.0 {
                e[i] = a.get(i, l);
            } else {
                for k in 0..=l {
                    let v = a.get(i, k) / scale;
                    a.set(i, k, v);
                    h += v * v;
                }
                let mut f = a.get(i, l);
                let g = if f >= 0.0 { -h.sqrt() } else { h.sqrt() };
                e[i] = scale * g;
                h -= f * g;
                a.set(i, l, f - g);
                f = 0.0;
                for j in 0..=l {
                    a.set(j, i, a.get(i, j) / h);
                    let mut g2 = 0.0;
                    for k in 0..=j {
                        g2 += a.get(j, k) * a.get(i, k);
                    }
                    for k in (j + 1)..=l {
                        g2 += a.get(k, j) * a.get(i, k);
                    }
                    e[j] = g2 / h;
                    f += e[j] * a.get(i, j);
                }
                let hh = f / (h + h);
                for j in 0..=l {
                    let fj = a.get(i, j);
                    let gj = e[j] - hh * fj;
                    e[j] = gj;
                    for k in 0..=j {
                        let v = a.get(j, k) - (fj * e[k] + gj * a.get(i, k));
                        a.set(j, k, v);
                    }
                }
            }
        } else {
            e[i] = a.get(i, l);
        }
        d[i] = h;
    }
    d[0] = 0.0;
    e[0] = 0.0;
    for i in 0..n {
        if d[i] != 0.0 {
            for j in 0..i {
                let mut g = 0.0;
                for k in 0..i {
                    g += a.get(i, k) * a.get(k, j);
                }
                for k in 0..i {
                    let v = a.get(k, j) - g * a.get(k, i);
                    a.set(k, j, v);
                }
            }
        }
        d[i] = a.get(i, i);
        a.set(i, i, 1.0);
        for j in 0..i {
            a.set(j, i, 0.0);
            a.set(i, j, 0.0);
        }
    }
}

/// QL iteration with implicit Wilkinson shifts on a symmetric tridiagonal
/// matrix `(d, e)`, rotating the **rows** of `zt` along (`zt` is the
/// transposed accumulator: row `i` holds what is mathematically column
/// `i` of `Z`). Row-pair rotations touch contiguous memory, which makes
/// the dominant O(n^3) rotation work vectorizable — see EXPERIMENTS.md
/// §Perf (L3) for the measured ~2x eigensolver speedup.
fn tqli(d: &mut [f64], e: &mut [f64], zt: &mut Matrix) -> Result<(), String> {
    let n = d.len();
    for i in 1..n {
        e[i - 1] = e[i];
    }
    e[n - 1] = 0.0;
    // absolute deflation floor: for spectra that decay below machine
    // precision (e.g. the paper's 0.9^j model at d = 300) the classical
    // relative test `|e[m]| <= eps * (|d[m]| + |d[m+1]|)` never fires on
    // the near-zero tail; deflating at eps * ||T|| perturbs eigenvalues
    // by at most O(eps * ||T||), which is the attainable accuracy anyway.
    let anorm = (0..n).map(|i| d[i].abs() + e[i].abs()).fold(0.0f64, f64::max);
    let floor = f64::EPSILON * anorm;
    for l in 0..n {
        let mut iter = 0;
        loop {
            // find the first decoupled block boundary
            let mut m = l;
            while m + 1 < n {
                let dd = d[m].abs() + d[m + 1].abs();
                if e[m].abs() <= f64::EPSILON * dd || e[m].abs() <= floor {
                    break;
                }
                m += 1;
            }
            if m == l {
                break;
            }
            iter += 1;
            if iter > 64 {
                return Err(format!("tqli: no convergence for eigenvalue {l} after 64 sweeps"));
            }
            let mut g = (d[l + 1] - d[l]) / (2.0 * e[l]);
            let mut r = pythag(g, 1.0);
            let sign_r = if g >= 0.0 { r.abs() } else { -r.abs() };
            g = d[m] - d[l] + e[l] / (g + sign_r);
            let (mut s, mut c) = (1.0, 1.0);
            let mut p = 0.0;
            let mut underflow = false;
            for i in (l..m).rev() {
                let f = s * e[i];
                let b = c * e[i];
                r = pythag(f, g);
                e[i + 1] = r;
                if r == 0.0 {
                    d[i + 1] -= p;
                    e[m] = 0.0;
                    underflow = true;
                    break;
                }
                s = f / r;
                c = g / r;
                g = d[i + 1] - p;
                r = (d[i] - g) * s + 2.0 * c * b;
                p = s * r;
                d[i + 1] = g + p;
                g = c * r - b;
                // accumulate the rotation into the (transposed)
                // eigenvector matrix: rows i and i+1, contiguous
                {
                    let (lo, hi) = zt.data_mut().split_at_mut((i + 1) * n);
                    let row_i = &mut lo[i * n..];
                    let row_i1 = &mut hi[..n];
                    for (a, b2) in row_i.iter_mut().zip(row_i1.iter_mut()) {
                        let fa = *b2;
                        *b2 = s * *a + c * fa;
                        *a = c * *a - s * fa;
                    }
                }
            }
            if underflow {
                continue;
            }
            d[l] -= p;
            e[l] = g;
            e[m] = 0.0;
        }
    }
    Ok(())
}

impl SymEigen {
    /// Full eigendecomposition of a symmetric matrix.
    ///
    /// The input is symmetrized defensively (`(A + A^T)/2`) to guard
    /// against accumulated round-off from callers. Panics on non-square
    /// input or (pathological) non-convergence.
    pub fn new(a: &Matrix) -> SymEigen {
        Self::try_new(a).expect("symmetric eigensolver failed to converge")
    }

    /// Non-panicking variant of [`SymEigen::new`].
    pub fn try_new(a: &Matrix) -> Result<SymEigen, String> {
        assert!(a.is_square(), "SymEigen: matrix must be square");
        let n = a.rows();
        if n == 0 {
            return Err("SymEigen: empty matrix".into());
        }
        let mut work = a.clone();
        work.symmetrize();
        let mut d = vec![0.0; n];
        let mut e = vec![0.0; n];
        if n == 1 {
            return Ok(SymEigen { values: vec![work.get(0, 0)], vectors: Matrix::identity(1) });
        }
        tred2(&mut work, &mut d, &mut e);
        // transpose the accumulated Q so tqli's rotations act on rows
        let mut zt = work.transpose();
        tqli(&mut d, &mut e, &mut zt)?;
        // sort descending; eigenvector i is row i of zt
        let mut idx: Vec<usize> = (0..n).collect();
        idx.sort_by(|&i, &j| d[j].partial_cmp(&d[i]).unwrap());
        let values: Vec<f64> = idx.iter().map(|&i| d[i]).collect();
        let mut vectors = Matrix::zeros(n, n);
        for (newc, &oldr) in idx.iter().enumerate() {
            let row = zt.row(oldr).to_vec();
            vectors.set_col(newc, &row);
        }
        Ok(SymEigen { values, vectors })
    }

    /// Eigenvalues, descending.
    pub fn values(&self) -> &[f64] {
        &self.values
    }

    /// Orthonormal eigenvector matrix (columns match `values`).
    pub fn vectors(&self) -> &Matrix {
        &self.vectors
    }

    /// Leading eigenvalue `lambda_1`.
    pub fn lambda1(&self) -> f64 {
        self.values[0]
    }

    /// Eigengap `lambda_1 - lambda_2` (0 for 1x1 matrices).
    pub fn eigengap(&self) -> f64 {
        if self.values.len() < 2 {
            0.0
        } else {
            self.values[0] - self.values[1]
        }
    }

    /// Leading unit eigenvector. The sign is normalized so that the entry
    /// of largest magnitude is positive (deterministic across runs); the
    /// *statistical* sign randomization required by Thm 3 is applied by
    /// the caller.
    pub fn leading(&self) -> Vec<f64> {
        let mut v = self.vectors.col(0);
        let mut imax = 0;
        for (i, x) in v.iter().enumerate() {
            if x.abs() > v[imax].abs() {
                imax = i;
            }
        }
        if v[imax] < 0.0 {
            for x in &mut v {
                *x = -*x;
            }
        }
        v
    }

    /// k-th unit eigenvector (0-based, descending order).
    pub fn eigvec(&self, k: usize) -> Vec<f64> {
        self.vectors.col(k)
    }

    /// Build `V f(lambda) V^T` — the spectral function calculus used for
    /// `C^{-1}` and `C^{-1/2}` in the Lemma-6 preconditioner.
    pub fn apply_fn(&self, f: impl Fn(f64) -> f64) -> Matrix {
        let n = self.values.len();
        // V * diag(f) -> scaled columns, then multiply by V^T
        let mut scaled = self.vectors.clone();
        for c in 0..n {
            let fc = f(self.values[c]);
            for r in 0..n {
                scaled.set(r, c, scaled.get(r, c) * fc);
            }
        }
        scaled.matmul(&self.vectors.transpose())
    }

    /// Apply `V f(lambda) V^T` to a single vector without forming the
    /// matrix: `O(d^2)` instead of `O(d^3)`. This is the hot path of the
    /// preconditioned solver (per-iteration `C^{-1} r`).
    pub fn apply_fn_vec(&self, f: impl Fn(f64) -> f64, x: &[f64], out: &mut [f64]) {
        let n = self.values.len();
        assert_eq!(x.len(), n);
        assert_eq!(out.len(), n);
        // coeffs = V^T x
        let mut coeffs = self.vectors.matvec_t(x);
        for (c, lam) in coeffs.iter_mut().zip(self.values.iter()) {
            *c *= f(*lam);
        }
        // out = V coeffs
        self.vectors.matvec_into(&coeffs, out);
    }

    /// Reconstruction `V diag(values) V^T` (for tests).
    pub fn reconstruct(&self) -> Matrix {
        self.apply_fn(|x| x)
    }
}

/// Leading eigenvector of a symmetric matrix — convenience wrapper used by
/// the one-shot estimators.
pub fn leading_eigvec(a: &Matrix) -> Vec<f64> {
    SymEigen::new(a).leading()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::vec_ops::{dot, norm};
    use crate::rng::Pcg64;

    fn random_sym(n: usize, seed: u64) -> Matrix {
        let mut rng = Pcg64::new(seed);
        let mut a = Matrix::zeros(n, n);
        for i in 0..n {
            for j in i..n {
                let v = rng.next_f64() * 2.0 - 1.0;
                a.set(i, j, v);
                a.set(j, i, v);
            }
        }
        a
    }

    #[test]
    fn diag_matrix_eigen() {
        let a = Matrix::diag(&[3.0, -1.0, 2.0]);
        let e = SymEigen::new(&a);
        assert!((e.values()[0] - 3.0).abs() < 1e-12);
        assert!((e.values()[1] - 2.0).abs() < 1e-12);
        assert!((e.values()[2] + 1.0).abs() < 1e-12);
        let v = e.leading();
        assert!((v[0].abs() - 1.0).abs() < 1e-10);
    }

    #[test]
    fn known_2x2() {
        // [[2,1],[1,2]] has eigenvalues 3, 1 with v1 = (1,1)/sqrt2
        let a = Matrix::from_vec(2, 2, vec![2., 1., 1., 2.]);
        let e = SymEigen::new(&a);
        assert!((e.lambda1() - 3.0).abs() < 1e-12);
        assert!((e.eigengap() - 2.0).abs() < 1e-12);
        let v = e.leading();
        assert!((v[0] - v[1]).abs() < 1e-10);
        assert!((norm(&v) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn reconstruction_matches_input() {
        for n in [1usize, 2, 3, 5, 17, 40] {
            let a = random_sym(n, 100 + n as u64);
            let e = SymEigen::new(&a);
            let r = e.reconstruct();
            let mut sym = a.clone();
            sym.symmetrize();
            assert!(
                r.sub(&sym).max_abs() < 1e-9 * (1.0 + sym.max_abs()),
                "reconstruction failed for n={n}: err={}",
                r.sub(&sym).max_abs()
            );
        }
    }

    #[test]
    fn eigenvectors_orthonormal() {
        let a = random_sym(23, 7);
        let e = SymEigen::new(&a);
        let v = e.vectors();
        let vtv = v.transpose().matmul(v);
        assert!(vtv.sub(&Matrix::identity(23)).max_abs() < 1e-10);
    }

    #[test]
    fn eigen_equation_residuals() {
        let a = random_sym(31, 9);
        let mut sym = a.clone();
        sym.symmetrize();
        let e = SymEigen::new(&a);
        for k in 0..31 {
            let vk = e.eigvec(k);
            let av = sym.matvec(&vk);
            let lv: Vec<f64> = vk.iter().map(|x| x * e.values()[k]).collect();
            let res: f64 = av.iter().zip(lv.iter()).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max);
            assert!(res < 1e-9, "residual {res} for pair {k}");
        }
    }

    #[test]
    fn values_sorted_descending() {
        let e = SymEigen::new(&random_sym(19, 11));
        for w in e.values().windows(2) {
            assert!(w[0] >= w[1] - 1e-14);
        }
    }

    #[test]
    fn apply_fn_inverse() {
        // f = 1/x on a PD matrix gives the inverse
        let mut a = random_sym(9, 13);
        // make it PD: A <- A^T A + I
        a = a.transpose().matmul(&a);
        a.axpy_mat(1.0, &Matrix::identity(9));
        let e = SymEigen::new(&a);
        let inv = e.apply_fn(|x| 1.0 / x);
        let prod = inv.matmul(&a);
        assert!(prod.sub(&Matrix::identity(9)).max_abs() < 1e-8);
    }

    #[test]
    fn apply_fn_sqrt_squares_back() {
        let mut a = random_sym(8, 17);
        a = a.transpose().matmul(&a); // PSD
        let e = SymEigen::new(&a);
        let half = e.apply_fn(|x| x.max(0.0).sqrt());
        let sq = half.matmul(&half);
        let mut sym = a.clone();
        sym.symmetrize();
        assert!(sq.sub(&sym).max_abs() < 1e-8);
    }

    #[test]
    fn apply_fn_vec_matches_matrix_apply() {
        let mut a = random_sym(12, 19);
        a = a.transpose().matmul(&a);
        a.axpy_mat(2.0, &Matrix::identity(12));
        let e = SymEigen::new(&a);
        let mut rng = Pcg64::new(23);
        let x: Vec<f64> = (0..12).map(|_| rng.next_f64() - 0.5).collect();
        let m = e.apply_fn(|t| 1.0 / t.sqrt());
        let want = m.matvec(&x);
        let mut got = vec![0.0; 12];
        e.apply_fn_vec(|t| 1.0 / t.sqrt(), &x, &mut got);
        for i in 0..12 {
            assert!((want[i] - got[i]).abs() < 1e-10);
        }
    }

    #[test]
    fn leading_sign_deterministic() {
        let a = random_sym(15, 29);
        let v1 = SymEigen::new(&a).leading();
        let v2 = SymEigen::new(&a.scale(1.0)).leading();
        for i in 0..15 {
            assert_eq!(v1[i], v2[i]);
        }
    }

    #[test]
    fn repeated_eigenvalues_ok() {
        // identity: all eigenvalues 1, any orthonormal basis valid
        let e = SymEigen::new(&Matrix::identity(6));
        for v in e.values() {
            assert!((v - 1.0).abs() < 1e-12);
        }
        let vtv = e.vectors().transpose().matmul(e.vectors());
        assert!(vtv.sub(&Matrix::identity(6)).max_abs() < 1e-10);
    }

    #[test]
    fn rank_one_plus_noise_leading_aligned() {
        // A = 5 u u^T + small noise: leading eigvec ~ u
        let n = 30;
        let mut rng = Pcg64::new(31);
        let mut u: Vec<f64> = (0..n).map(|_| rng.next_gaussian()).collect();
        let nu = norm(&u);
        u.iter_mut().for_each(|x| *x /= nu);
        let mut a = Matrix::outer(&u, &u).scale(5.0);
        let noise = random_sym(n, 37).scale(0.01);
        a.axpy_mat(1.0, &noise);
        let v = SymEigen::new(&a).leading();
        assert!(dot(&v, &u).abs() > 0.999, "alignment {}", dot(&v, &u).abs());
    }

    #[test]
    fn matches_jacobi_cross_check() {
        for n in [3usize, 6, 12] {
            let a = random_sym(n, 200 + n as u64);
            let e1 = SymEigen::new(&a);
            let e2 = crate::linalg::jacobi::jacobi_eigen(&a);
            for k in 0..n {
                assert!(
                    (e1.values()[k] - e2.0[k]).abs() < 1e-9,
                    "eigenvalue {k} mismatch: {} vs {}",
                    e1.values()[k],
                    e2.0[k]
                );
            }
        }
    }
}
