//! Row-major dense `f64` matrix with the kernels the coordinator needs.
//!
//! Layout: `data[r * cols + c]`. The GEMM is a cache-blocked i-k-j loop —
//! the j-inner ordering makes the innermost loop a contiguous
//! multiply-accumulate over both `b` and `out`, which LLVM auto-vectorizes.

use std::fmt;

/// Dense row-major matrix of `f64`.
#[derive(Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

/// Cache block edge for the blocked GEMM (tuned in `bench_linalg`).
const GEMM_BLOCK: usize = 64;

impl Matrix {
    /// Zero matrix of shape `rows x cols`.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity matrix of size `n`.
    pub fn identity(n: usize) -> Self {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    /// Build from a row-major data vector. Panics if sizes disagree.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "matrix data length mismatch");
        Matrix { rows, cols, data }
    }

    /// Build from a slice of rows. Panics if the rows are ragged.
    pub fn from_rows(rows: &[Vec<f64>]) -> Self {
        assert!(!rows.is_empty(), "from_rows: empty");
        let cols = rows[0].len();
        let mut data = Vec::with_capacity(rows.len() * cols);
        for r in rows {
            assert_eq!(r.len(), cols, "from_rows: ragged rows");
            data.extend_from_slice(r);
        }
        Matrix { rows: rows.len(), cols, data }
    }

    /// Diagonal matrix from a vector.
    pub fn diag(d: &[f64]) -> Self {
        let n = d.len();
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = d[i];
        }
        m
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn is_square(&self) -> bool {
        self.rows == self.cols
    }

    /// Raw row-major data.
    pub fn data(&self) -> &[f64] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    #[inline(always)]
    pub fn get(&self, r: usize, c: usize) -> f64 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline(always)]
    pub fn set(&mut self, r: usize, c: usize, v: f64) {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c] = v;
    }

    #[inline(always)]
    pub fn row(&self, r: usize) -> &[f64] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline(always)]
    pub fn row_mut(&mut self, r: usize) -> &mut [f64] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Extract column `c` as a fresh vector.
    pub fn col(&self, c: usize) -> Vec<f64> {
        (0..self.rows).map(|r| self.get(r, c)).collect()
    }

    pub fn set_col(&mut self, c: usize, v: &[f64]) {
        assert_eq!(v.len(), self.rows);
        for r in 0..self.rows {
            self.set(r, c, v[r]);
        }
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                t.data[c * self.rows + r] = self.data[r * self.cols + c];
            }
        }
        t
    }

    /// `self * other` with cache-blocked i-k-j GEMM, parallelized over
    /// output row panels per the process-global thread budget
    /// ([`crate::linalg::compute_threads`]).
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        self.matmul_threads(other, crate::linalg::compute_threads())
    }

    /// [`Matrix::matmul`] with an explicit thread count. Each output row
    /// is computed by exactly one thread in the same blocked loop order
    /// as the scalar kernel, so the result is **bit-identical** at any
    /// thread count (owner-computes: no cross-thread reduction).
    pub fn matmul_threads(&self, other: &Matrix, threads: usize) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul: inner dims mismatch");
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut out = Matrix::zeros(m, n);
        // Small products are not worth a thread spawn.
        let t = if m.saturating_mul(k).saturating_mul(n) < 1 << 16 { 1 } else { threads };
        let panels = crate::linalg::threads::row_panels(m, t);
        if panels.len() == 1 {
            self.gemm_panel(other, 0, &mut out.data);
            return out;
        }
        std::thread::scope(|s| {
            let mut rest: &mut [f64] = &mut out.data;
            for &(r0, r1) in &panels {
                let (panel, tail) = std::mem::take(&mut rest).split_at_mut((r1 - r0) * n);
                rest = tail;
                s.spawn(move || self.gemm_panel(other, r0, panel));
            }
        });
        out
    }

    /// Blocked i-k-j GEMM for output rows `r0..r0 + out_panel.len()/n`.
    fn gemm_panel(&self, other: &Matrix, r0: usize, out_panel: &mut [f64]) {
        let (k, n) = (self.cols, other.cols);
        let rows = out_panel.len() / n.max(1);
        for ib in (0..rows).step_by(GEMM_BLOCK) {
            let imax = (ib + GEMM_BLOCK).min(rows);
            for kb in (0..k).step_by(GEMM_BLOCK) {
                let kmax = (kb + GEMM_BLOCK).min(k);
                for i in ib..imax {
                    let arow = &self.data[(r0 + i) * k..(r0 + i + 1) * k];
                    let orow = &mut out_panel[i * n..(i + 1) * n];
                    for p in kb..kmax {
                        let a = arow[p];
                        if a == 0.0 {
                            continue;
                        }
                        let brow = &other.data[p * n..(p + 1) * n];
                        for j in 0..n {
                            orow[j] += a * brow[j];
                        }
                    }
                }
            }
        }
    }

    /// `self * v` (GEMV). Output has length `rows`.
    pub fn matvec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(v.len(), self.cols, "matvec: dim mismatch");
        let mut out = vec![0.0; self.rows];
        self.matvec_into(v, &mut out);
        out
    }

    /// Allocation-free GEMV into a caller-provided buffer.
    pub fn matvec_into(&self, v: &[f64], out: &mut [f64]) {
        assert_eq!(v.len(), self.cols);
        assert_eq!(out.len(), self.rows);
        for r in 0..self.rows {
            let row = &self.data[r * self.cols..(r + 1) * self.cols];
            let mut acc = 0.0;
            for (a, b) in row.iter().zip(v.iter()) {
                acc += a * b;
            }
            out[r] = acc;
        }
    }

    /// `self^T * v`. Output has length `cols`. Row-major friendly: streams
    /// rows and accumulates `v[r] * row` (axpy), contiguous in memory.
    pub fn matvec_t(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(v.len(), self.rows, "matvec_t: dim mismatch");
        let mut out = vec![0.0; self.cols];
        self.matvec_t_into(v, &mut out);
        out
    }

    /// Allocation-free transposed GEMV into a caller-provided buffer.
    pub fn matvec_t_into(&self, v: &[f64], out: &mut [f64]) {
        assert_eq!(v.len(), self.rows);
        assert_eq!(out.len(), self.cols);
        out.iter_mut().for_each(|x| *x = 0.0);
        for r in 0..self.rows {
            let a = v[r];
            if a == 0.0 {
                continue;
            }
            let row = &self.data[r * self.cols..(r + 1) * self.cols];
            for (o, b) in out.iter_mut().zip(row.iter()) {
                *o += a * b;
            }
        }
    }

    /// Symmetric rank-k update `self^T * self` (SYRK): the empirical Gram /
    /// covariance kernel. Only the upper triangle is computed, then
    /// mirrored.
    pub fn syrk_t(&self) -> Matrix {
        let (n, d) = (self.rows, self.cols);
        let mut g = Matrix::zeros(d, d);
        for r in 0..n {
            let row = &self.data[r * d..(r + 1) * d];
            for i in 0..d {
                let a = row[i];
                if a == 0.0 {
                    continue;
                }
                let grow = &mut g.data[i * d..(i + 1) * d];
                for j in i..d {
                    grow[j] += a * row[j];
                }
            }
        }
        // mirror upper -> lower
        for i in 0..d {
            for j in (i + 1)..d {
                let v = g.data[i * d + j];
                g.data[j * d + i] = v;
            }
        }
        g
    }

    /// Element-wise `self + other`.
    pub fn add(&self, other: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a + b).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    /// Element-wise `self - other`.
    pub fn sub(&self, other: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    /// In-place `self += s * other`.
    pub fn axpy_mat(&mut self, s: f64, other: &Matrix) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += s * b;
        }
    }

    /// Scaled copy `s * self`.
    pub fn scale(&self, s: f64) -> Matrix {
        let data = self.data.iter().map(|a| a * s).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    /// In-place scale.
    pub fn scale_mut(&mut self, s: f64) {
        for a in &mut self.data {
            *a *= s;
        }
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|a| a * a).sum::<f64>().sqrt()
    }

    /// Spectral norm of a **symmetric** matrix via its eigenvalues.
    /// Panics if not square.
    pub fn sym_spectral_norm(&self) -> f64 {
        assert!(self.is_square());
        let eig = crate::linalg::eigen::SymEigen::new(self);
        eig.values().iter().fold(0.0f64, |acc, &v| acc.max(v.abs()))
    }

    /// Max absolute entry.
    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0f64, |acc, &v| acc.max(v.abs()))
    }

    /// Symmetrize in place: `(A + A^T)/2`. Cheap guard against numerical
    /// asymmetry before eigensolves.
    pub fn symmetrize(&mut self) {
        assert!(self.is_square());
        let n = self.rows;
        for i in 0..n {
            for j in (i + 1)..n {
                let v = 0.5 * (self.data[i * n + j] + self.data[j * n + i]);
                self.data[i * n + j] = v;
                self.data[j * n + i] = v;
            }
        }
    }

    /// Outer product `u v^T`.
    pub fn outer(u: &[f64], v: &[f64]) -> Matrix {
        let mut m = Matrix::zeros(u.len(), v.len());
        for (i, &a) in u.iter().enumerate() {
            if a == 0.0 {
                continue;
            }
            let row = &mut m.data[i * v.len()..(i + 1) * v.len()];
            for (o, &b) in row.iter_mut().zip(v.iter()) {
                *o = a * b;
            }
        }
        m
    }

    /// Trace. Panics if not square.
    pub fn trace(&self) -> f64 {
        assert!(self.is_square());
        (0..self.rows).map(|i| self.data[i * self.cols + i]).sum()
    }
}

impl fmt::Debug for Matrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Matrix {}x{} [", self.rows, self.cols)?;
        let show = self.rows.min(8);
        for r in 0..show {
            write!(f, "  [")?;
            for c in 0..self.cols.min(8) {
                write!(f, "{:+.4e} ", self.get(r, c))?;
            }
            writeln!(f, "{}]", if self.cols > 8 { "…" } else { "" })?;
        }
        if self.rows > 8 {
            writeln!(f, "  …")?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::vec_ops;

    #[test]
    fn identity_matmul_is_noop() {
        let a = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let i2 = Matrix::identity(2);
        let i3 = Matrix::identity(3);
        assert_eq!(i2.matmul(&a).data(), a.data());
        assert_eq!(a.matmul(&i3).data(), a.data());
    }

    #[test]
    fn matmul_known_values() {
        let a = Matrix::from_vec(2, 2, vec![1., 2., 3., 4.]);
        let b = Matrix::from_vec(2, 2, vec![5., 6., 7., 8.]);
        let c = a.matmul(&b);
        assert_eq!(c.data(), &[19., 22., 43., 50.]);
    }

    #[test]
    fn matmul_blocked_matches_naive_rectangular() {
        // exercise the blocking path with sizes > GEMM_BLOCK
        let (m, k, n) = (70, 65, 80);
        let mut rng = crate::rng::Pcg64::new(1);
        let a = Matrix::from_vec(m, k, (0..m * k).map(|_| rng.next_f64() - 0.5).collect());
        let b = Matrix::from_vec(k, n, (0..k * n).map(|_| rng.next_f64() - 0.5).collect());
        let c = a.matmul(&b);
        // naive reference
        for i in (0..m).step_by(17) {
            for j in (0..n).step_by(13) {
                let mut acc = 0.0;
                for p in 0..k {
                    acc += a.get(i, p) * b.get(p, j);
                }
                assert!((acc - c.get(i, j)).abs() < 1e-10, "mismatch at ({i},{j})");
            }
        }
    }

    #[test]
    fn matmul_threads_bit_identical_to_scalar() {
        // owner-computes partitioning: per-row loop order is unchanged,
        // so every thread count must produce the exact same bits
        let (m, k, n) = (90, 70, 40); // m*k*n > the spawn threshold
        let mut rng = crate::rng::Pcg64::new(6);
        let a = Matrix::from_vec(m, k, (0..m * k).map(|_| rng.next_f64() - 0.5).collect());
        let b = Matrix::from_vec(k, n, (0..k * n).map(|_| rng.next_f64() - 0.5).collect());
        let scalar = a.matmul_threads(&b, 1);
        for t in [2, 3, 8, 64] {
            let threaded = a.matmul_threads(&b, t);
            assert_eq!(threaded.data(), scalar.data(), "t={t} must be bit-identical");
        }
    }

    #[test]
    fn matvec_matches_matmul() {
        let mut rng = crate::rng::Pcg64::new(2);
        let a = Matrix::from_vec(9, 7, (0..63).map(|_| rng.next_f64()).collect());
        let v: Vec<f64> = (0..7).map(|_| rng.next_f64()).collect();
        let got = a.matvec(&v);
        let vm = Matrix::from_vec(7, 1, v.clone());
        let want = a.matmul(&vm);
        for i in 0..9 {
            assert!((got[i] - want.get(i, 0)).abs() < 1e-12);
        }
    }

    #[test]
    fn matvec_t_matches_transpose_matvec() {
        let mut rng = crate::rng::Pcg64::new(3);
        let a = Matrix::from_vec(11, 5, (0..55).map(|_| rng.next_f64()).collect());
        let v: Vec<f64> = (0..11).map(|_| rng.next_f64()).collect();
        let got = a.matvec_t(&v);
        let want = a.transpose().matvec(&v);
        for i in 0..5 {
            assert!((got[i] - want[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn syrk_matches_explicit_gram() {
        let mut rng = crate::rng::Pcg64::new(4);
        let a = Matrix::from_vec(20, 6, (0..120).map(|_| rng.next_f64() - 0.5).collect());
        let g = a.syrk_t();
        let want = a.transpose().matmul(&a);
        assert!(g.sub(&want).max_abs() < 1e-12);
        // symmetry
        for i in 0..6 {
            for j in 0..6 {
                assert_eq!(g.get(i, j), g.get(j, i));
            }
        }
    }

    #[test]
    fn transpose_involution() {
        let mut rng = crate::rng::Pcg64::new(5);
        let a = Matrix::from_vec(4, 9, (0..36).map(|_| rng.next_f64()).collect());
        assert_eq!(a.transpose().transpose().data(), a.data());
    }

    #[test]
    fn outer_product_rank_one() {
        let u = vec![1., 2., 3.];
        let v = vec![4., 5.];
        let m = Matrix::outer(&u, &v);
        assert_eq!(m.rows(), 3);
        assert_eq!(m.cols(), 2);
        assert_eq!(m.get(2, 1), 15.0);
        // every 2x2 minor is singular
        let det = m.get(0, 0) * m.get(1, 1) - m.get(0, 1) * m.get(1, 0);
        assert!(det.abs() < 1e-12);
    }

    #[test]
    fn add_sub_scale_roundtrip() {
        let a = Matrix::from_vec(2, 2, vec![1., 2., 3., 4.]);
        let b = a.scale(2.0);
        let c = b.sub(&a);
        assert_eq!(c.data(), a.data());
        let d = a.add(&a);
        assert_eq!(d.data(), b.data());
    }

    #[test]
    fn trace_and_fro() {
        let a = Matrix::from_vec(2, 2, vec![3., 0., 0., 4.]);
        assert_eq!(a.trace(), 7.0);
        assert!((a.fro_norm() - 5.0).abs() < 1e-15);
    }

    #[test]
    fn col_set_col_roundtrip() {
        let mut a = Matrix::zeros(3, 2);
        a.set_col(1, &[1., 2., 3.]);
        assert_eq!(a.col(1), vec![1., 2., 3.]);
        assert_eq!(a.col(0), vec![0., 0., 0.]);
    }

    #[test]
    fn matvec_into_no_alloc_matches() {
        let a = Matrix::from_vec(3, 3, vec![1., 0., 0., 0., 2., 0., 0., 0., 3.]);
        let v = vec![1., 1., 1.];
        let mut out = vec![0.0; 3];
        a.matvec_into(&v, &mut out);
        assert_eq!(out, vec![1., 2., 3.]);
    }

    #[test]
    fn symmetrize_fixes_asymmetry() {
        let mut a = Matrix::from_vec(2, 2, vec![1., 2., 4., 1.]);
        a.symmetrize();
        assert_eq!(a.get(0, 1), 3.0);
        assert_eq!(a.get(1, 0), 3.0);
    }

    #[test]
    fn sym_spectral_norm_diag() {
        let a = Matrix::diag(&[1.0, -7.0, 3.0]);
        assert!((a.sym_spectral_norm() - 7.0).abs() < 1e-10);
    }

    #[test]
    fn axpy_mat_accumulates() {
        let mut a = Matrix::identity(2);
        let b = Matrix::identity(2);
        a.axpy_mat(3.0, &b);
        assert_eq!(a.get(0, 0), 4.0);
        assert_eq!(a.get(0, 1), 0.0);
    }

    #[test]
    fn normalized_col_unit_norm() {
        let mut a = Matrix::zeros(3, 1);
        a.set_col(0, &[3., 0., 4.]);
        let mut c = a.col(0);
        vec_ops::normalize(&mut c);
        assert!((vec_ops::norm(&c) - 1.0).abs() < 1e-15);
    }
}
