//! Schedule-enumerating model checker: a small, dependency-free,
//! loom-style explorer.
//!
//! A [`Model`] describes a finite concurrent system as a cloneable,
//! hashable state plus per-thread atomic steps; the [`Explorer`]
//! enumerates every interleaving of those steps up to a
//! **bounded-preemption** cap, by depth-first search with state cloning
//! at each choice point (replay-free: we fork the state instead of
//! re-running prefixes) and a visited set over
//! `(state, last-thread, remaining-budget)` so confluent interleavings
//! — different orders that reach the same state — are explored once.
//! The memoization is sound for safety and deadlock detection because
//! a repeated key has an identical subtree; it does assume models make
//! monotone progress (a genuine livelock cycle would be pruned as
//! "visited", not reported — our models consume a finite reply supply,
//! so every step chain terminates).
//!
//! Bounded preemption (CHESS-style): continuing the thread that took
//! the previous step is free; switching *away from a thread that could
//! still run* costs one unit of a preemption budget. Forced switches
//! (the previous thread blocked or finished) are free. Empirically,
//! almost all real concurrency bugs manifest within 2 preemptions, and
//! the bound keeps the schedule space tractable — the router model
//! tests run with a budget of 2–3 (ISSUE 7's acceptance floor is 2).
//!
//! Detected violations:
//! * a step or final-state check returning `Err` (safety — e.g. a
//!   reply routed twice, bills diverging from the aggregate ledger);
//! * **stuck states**: no thread is runnable but some thread is
//!   unfinished — a deadlock or lost wakeup (termination, within the
//!   model's convention that a blocking wait is a disabled thread).

use std::collections::HashSet;
use std::hash::Hash;

/// A finite concurrent system the explorer can enumerate.
///
/// `step` must be *deterministic given the state*: all nondeterminism
/// lives in the scheduler's choice of which thread steps next. A thread
/// is scheduled only while `enabled` and not `finished`.
pub trait Model {
    type State: Clone + Eq + Hash;

    /// Number of threads (fixed for the run).
    fn threads(&self) -> usize;

    fn init(&self) -> Self::State;

    /// Can this thread take a step right now? (`false` models a thread
    /// blocked on a lock / channel / condvar.)
    fn enabled(&self, st: &Self::State, tid: usize) -> bool;

    /// Has this thread run to completion? (Distinct from temporarily
    /// disabled: a finished thread never becomes enabled again.)
    fn finished(&self, st: &Self::State, tid: usize) -> bool;

    /// Execute one atomic step of `tid`. `Err` is a safety violation
    /// reported with the schedule that produced it.
    fn step(&self, st: &mut Self::State, tid: usize) -> Result<(), String>;

    /// Checked once per fully-terminated schedule (all threads
    /// finished).
    fn final_check(&self, st: &Self::State) -> Result<(), String>;
}

/// A violating execution: the thread-id schedule that led to it and the
/// model's message.
#[derive(Debug, Clone)]
pub struct Violation {
    pub schedule: Vec<usize>,
    pub message: String,
}

/// Exploration outcome.
#[derive(Debug)]
pub struct Report {
    /// Distinct terminal states reached (leaves of the memoized DFS).
    pub schedules: usize,
    /// True if the enumeration stopped at `max_schedules` instead of
    /// exhausting the (preemption-bounded) space.
    pub truncated: bool,
    pub violation: Option<Violation>,
}

impl Report {
    /// Panic with the witness schedule if a violation was found —
    /// convenience for tests.
    pub fn assert_clean(&self, what: &str) {
        if let Some(v) = &self.violation {
            panic!(
                "model check '{what}' failed after {} schedules: {} (schedule: {:?})",
                self.schedules, v.message, v.schedule
            );
        }
    }
}

/// DFS over schedules with a bounded-preemption cap.
pub struct Explorer {
    /// Max number of *preemptive* context switches per schedule
    /// (switching away from a still-runnable thread).
    pub max_preemptions: usize,
    /// Hard cap on enumerated terminal states (guards against a model
    /// bug exploding the space; `truncated` reports if it was hit).
    pub max_schedules: usize,
}

/// Visited-set key: model state plus the scheduler context that
/// determines the subtree (last thread stepped, remaining budget).
type SeenKey<S> = (S, Option<usize>, usize);

struct Search<'a, M: Model> {
    model: &'a M,
    max_schedules: usize,
    visited: HashSet<SeenKey<M::State>>,
    schedule: Vec<usize>,
    report: Report,
    on_leaf: &'a mut dyn FnMut(&M::State),
}

impl Explorer {
    pub fn new(max_preemptions: usize) -> Self {
        Self { max_preemptions, max_schedules: 1_000_000 }
    }

    pub fn explore<M: Model>(&self, model: &M) -> Report {
        self.explore_leaves(model, &mut |_| {})
    }

    /// Like [`Explorer::explore`], additionally invoking `on_leaf` on
    /// the final state of every violation-free fully-terminated
    /// schedule — used by tests to assert that qualitatively different
    /// outcomes (e.g. straggler billed vs. straggler dropped) are both
    /// actually reached.
    pub fn explore_leaves<M: Model>(
        &self,
        model: &M,
        on_leaf: &mut dyn FnMut(&M::State),
    ) -> Report {
        let mut search = Search {
            model,
            max_schedules: self.max_schedules,
            visited: HashSet::new(),
            schedule: Vec::new(),
            report: Report { schedules: 0, truncated: false, violation: None },
            on_leaf,
        };
        search.dfs(model.init(), None, self.max_preemptions);
        search.report
    }
}

impl<M: Model> Search<'_, M> {
    /// Returns `true` to stop the search (violation found or cap hit).
    fn dfs(&mut self, st: M::State, last: Option<usize>, budget: usize) -> bool {
        if self.report.schedules >= self.max_schedules {
            self.report.truncated = true;
            return true;
        }
        if !self.visited.insert((st.clone(), last, budget)) {
            return false; // identical subtree already explored
        }
        let n = self.model.threads();
        let runnable: Vec<usize> = (0..n)
            .filter(|&t| !self.model.finished(&st, t) && self.model.enabled(&st, t))
            .collect();
        if runnable.is_empty() {
            self.report.schedules += 1;
            let unfinished: Vec<usize> =
                (0..n).filter(|&t| !self.model.finished(&st, t)).collect();
            let outcome = if unfinished.is_empty() {
                self.model.final_check(&st)
            } else {
                Err(format!(
                    "stuck: threads {unfinished:?} never finished and none is runnable \
                     (deadlock or lost wakeup)"
                ))
            };
            return match outcome {
                Ok(()) => {
                    (self.on_leaf)(&st);
                    false
                }
                Err(message) => {
                    self.report.violation =
                        Some(Violation { schedule: self.schedule.clone(), message });
                    true
                }
            };
        }
        let last_still_runnable = last.is_some_and(|t| runnable.contains(&t));
        for &tid in &runnable {
            // switching away from a thread that could have continued is
            // a preemption; forced switches and continuations are free
            let next_budget = if last_still_runnable && Some(tid) != last {
                match budget.checked_sub(1) {
                    Some(b) => b,
                    None => continue, // out of preemption budget
                }
            } else {
                budget
            };
            let mut next = st.clone();
            self.schedule.push(tid);
            let stop = match self.model.step(&mut next, tid) {
                Err(message) => {
                    self.report.violation =
                        Some(Violation { schedule: self.schedule.clone(), message });
                    true
                }
                Ok(()) => self.dfs(next, Some(tid), next_budget),
            };
            self.schedule.pop();
            if stop {
                return true;
            }
        }
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Two threads each do `tmp = x; x = tmp + 1` in two separate steps:
    /// the classic lost-update race. The explorer must find the
    /// interleaving where the final value is 1, not 2 — and must NOT
    /// find it with a preemption budget of 0 (serialized schedules
    /// only), which pins down the budget semantics.
    struct LostUpdate;

    #[derive(Clone, PartialEq, Eq, Hash)]
    struct LuState {
        x: u32,
        tmp: [u32; 2],
        pc: [usize; 2],
    }

    impl Model for LostUpdate {
        type State = LuState;
        fn threads(&self) -> usize {
            2
        }
        fn init(&self) -> LuState {
            LuState { x: 0, tmp: [0, 0], pc: [0, 0] }
        }
        fn enabled(&self, _st: &LuState, _tid: usize) -> bool {
            true
        }
        fn finished(&self, st: &LuState, tid: usize) -> bool {
            st.pc[tid] >= 2
        }
        fn step(&self, st: &mut LuState, tid: usize) -> Result<(), String> {
            match st.pc[tid] {
                0 => st.tmp[tid] = st.x,
                _ => st.x = st.tmp[tid] + 1,
            }
            st.pc[tid] += 1;
            Ok(())
        }
        fn final_check(&self, st: &LuState) -> Result<(), String> {
            if st.x == 2 {
                Ok(())
            } else {
                Err(format!("lost update: x = {} after two increments", st.x))
            }
        }
    }

    #[test]
    fn serialized_schedules_miss_the_race() {
        let report = Explorer::new(0).explore(&LostUpdate);
        assert!(report.violation.is_none(), "budget 0 must only see serialized runs");
        // exactly the two serial orders
        assert_eq!(report.schedules, 2);
    }

    #[test]
    fn one_preemption_finds_the_race() {
        let report = Explorer::new(1).explore(&LostUpdate);
        let v = report.violation.expect("racy interleaving must be found with budget 1");
        assert!(v.message.contains("lost update"), "{}", v.message);
        // the witness interleaves the reads before either write
        assert!(v.schedule.len() >= 3);
    }

    /// A notify that can be dropped when it races ahead of the park —
    /// the explorer must report the stuck waiter, not hang or pass.
    struct LostWakeup;

    #[derive(Clone, PartialEq, Eq, Hash)]
    struct LwState {
        flag_set_with_notify: bool,
        parked: bool,
        done: [bool; 2],
    }

    impl Model for LostWakeup {
        type State = LwState;
        fn threads(&self) -> usize {
            2
        }
        fn init(&self) -> LwState {
            LwState { flag_set_with_notify: false, parked: false, done: [false, false] }
        }
        fn enabled(&self, st: &LwState, tid: usize) -> bool {
            match tid {
                0 => !st.parked || st.flag_set_with_notify,
                _ => true,
            }
        }
        fn finished(&self, st: &LwState, tid: usize) -> bool {
            st.done[tid]
        }
        fn step(&self, st: &mut LwState, tid: usize) -> Result<(), String> {
            if tid == 0 {
                if st.parked || st.flag_set_with_notify {
                    st.done[0] = true; // woke up (or never needed to park)
                } else {
                    st.parked = true; // missed the flag: park
                }
            } else {
                // BUG modeled: the flag is published with a wakeup only
                // if the waiter has not parked yet — i.e. the notify is
                // dropped when it loses the race with the park.
                if !st.parked {
                    st.flag_set_with_notify = true;
                }
                st.done[1] = true;
            }
            Ok(())
        }
        fn final_check(&self, _st: &LwState) -> Result<(), String> {
            Ok(())
        }
    }

    #[test]
    fn stuck_state_is_reported_as_violation() {
        let report = Explorer::new(2).explore(&LostWakeup);
        let v = report.violation.expect("the dropped-notify deadlock must be found");
        assert!(v.message.contains("stuck"), "{}", v.message);
    }

    #[test]
    fn leaf_observer_sees_every_clean_terminal_state() {
        let mut finals = Vec::new();
        let report = Explorer { max_preemptions: 0, max_schedules: 1_000_000 }
            .explore_leaves(&LostUpdate, &mut |st| finals.push(st.x));
        assert!(report.violation.is_none());
        assert_eq!(finals, vec![2, 2]);
    }

    #[test]
    fn schedule_cap_reports_truncation() {
        let report = Explorer { max_preemptions: 2, max_schedules: 1 }.explore(&LostUpdate);
        assert!(report.truncated);
    }
}
