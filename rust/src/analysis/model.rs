//! Miniature model of the split-phase reply router
//! (`cluster::Router`) for the schedule-enumerating checker.
//!
//! The model abstracts the real machine (PR 5) to its decision
//! structure, with one atomic step per lock-protected critical section:
//!
//! * **Sessions** run scripted programs over their ops: `Submit` opens
//!   a slot (seq → owner/expected/got) and arms the workers' replies;
//!   `Complete` is the await loop — collect when the slot is full,
//!   else become the **driver** by taking the router receiver (`rx`)
//!   if free, else park on the condvar; the driver routes one wire
//!   reply per step and releases `rx` when its own slot fills;
//!   `Timeout` is the deadline path — retire the slot to an `Inflight`
//!   straggler record (or to nothing, modeling an aged-out record);
//!   `Close` drops the session's billing identity (the real code's
//!   `Weak<SessionCore>` upgrade failure).
//! * **Injectors** (one thread per reply) model network delay: each
//!   moves one armed reply onto the wire at a nondeterministic time; a
//!   `late` reply (straggler) only after its round was retired. A
//!   reply whose injector never fires before the run ends models a
//!   reply sitting in the channel at shutdown.
//! * **Routing** bills an open slot's owner, else the straggler
//!   record's owner-if-not-closed, else drops the reply on the floor —
//!   exactly `Router::route_reply`'s contract.
//!
//! Checked across **all** explored interleavings (see
//! [`super::sched`]):
//! * every reply is routed-or-dropped **exactly once** (a wire reply
//!   consumed twice is an immediate step error; one never consumed is
//!   accounted as dropped-at-shutdown by the final check);
//! * **no double-billing**: Σ per-session bills == the aggregate
//!   ledger, and a session whose script never times out is billed
//!   exactly its own replies;
//! * **termination**: every schedule ends with all threads finished —
//!   a parked session nobody wakes (lost wakeup) or a stuck driver is
//!   reported by the explorer as a stuck state.
//!
//! [`Bug`] variants re-introduce real bug classes (double-counted
//! aggregate, straggler billed to the *draining* session instead of
//! the issuer, a collect that skips the condvar notify); the tests
//! assert the checker actually catches each one — the
//! false-negative guard ISSUE 7 asks for.

use std::collections::{BTreeMap, VecDeque};

use super::sched::Model;

/// One scripted session operation.
#[derive(Clone, Debug)]
pub enum Op {
    /// Open a slot for `seq` expecting `expected` replies, arming every
    /// [`ReplySpec`] with this `seq`.
    Submit { seq: u64, expected: usize },
    /// Await-loop until the `seq` slot is full, draining the router
    /// while driver (see module docs), then collect it.
    Complete { seq: u64 },
    /// Deadline path: retire the `seq` slot to a straggler record
    /// (`aged: true` models the record itself having been pruned).
    Timeout { seq: u64, aged: bool },
    /// Drop the session's billing identity.
    Close,
}

/// One worker reply the scenario will (eventually) deliver.
#[derive(Clone, Debug)]
pub struct ReplySpec {
    pub seq: u64,
    /// Straggler: deliverable only after `seq` has been retired.
    pub late: bool,
}

/// A scripted session.
#[derive(Clone, Debug, Default)]
pub struct SessionScript {
    pub ops: Vec<Op>,
    /// `Some(n)`: this session's final bill must be exactly `n`
    /// responses (set for sessions whose script makes the bill
    /// schedule-independent — e.g. a plain submit/complete/close
    /// session is always billed exactly its own replies).
    pub exact_bill: Option<u64>,
}

/// A complete scenario: session scripts plus the reply supply.
#[derive(Clone, Debug)]
pub struct Scenario {
    pub name: &'static str,
    pub sessions: Vec<SessionScript>,
    pub replies: Vec<ReplySpec>,
}

/// Seeded bugs for detector self-tests (ISSUE 7: guard the checker
/// against false negatives).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Bug {
    None,
    /// Billing increments the aggregate ledger twice per response.
    DoubleCountAggregate,
    /// A drained straggler is billed to the session driving the router
    /// instead of the round's issuer.
    BillDrainerOnStraggler,
    /// Collecting a full slot skips the condvar notify.
    MissedWakeup,
}

#[derive(Clone, PartialEq, Eq, Hash)]
struct MSlot {
    owner: usize,
    expected: usize,
    got: usize,
}

#[derive(Clone, PartialEq, Eq, Hash)]
struct MInflight {
    owner: usize,
    outstanding: usize,
}

/// The model state: one atomic step per real critical section.
#[derive(Clone, PartialEq, Eq, Hash)]
pub struct RouterState {
    /// Reply ids sitting in the leader's reply channel, FIFO.
    wire: VecDeque<usize>,
    /// Per reply: its round was submitted (the worker owes it).
    armed: Vec<bool>,
    /// Per reply: the injector moved it onto the wire.
    injected: Vec<bool>,
    /// Per reply: consumed from the wire (routed or floor-dropped).
    routed: Vec<bool>,
    open: BTreeMap<u64, MSlot>,
    inflight: BTreeMap<u64, MInflight>,
    /// Seqs whose slot is gone (collected or timed out) — gates `late`
    /// replies.
    retired: Vec<u64>,
    /// Which session holds the router receiver (the driver).
    rx_held: Option<usize>,
    /// Per session: parked on the router condvar.
    parked: Vec<bool>,
    closed: Vec<bool>,
    /// Per session: responses billed (`CommStats.responses_received`).
    bills: Vec<u64>,
    /// The cluster-wide aggregate ledger.
    agg: u64,
    /// Replies dropped on the floor (closed/aged straggler).
    dropped: u64,
    /// Per session: program counter into its script.
    pc: Vec<usize>,
}

impl RouterState {
    pub fn bills(&self) -> &[u64] {
        &self.bills
    }
    pub fn dropped(&self) -> u64 {
        self.dropped
    }
}

/// The checkable model: a scenario plus an optional seeded bug.
pub struct RouterModel {
    pub scenario: Scenario,
    pub bug: Bug,
}

impl RouterModel {
    pub fn new(scenario: Scenario) -> Self {
        Self { scenario, bug: Bug::None }
    }

    pub fn with_bug(scenario: Scenario, bug: Bug) -> Self {
        Self { scenario, bug }
    }

    fn session_count(&self) -> usize {
        self.scenario.sessions.len()
    }

    /// Wake every parked session (the router condvar is notify_all).
    fn unpark_all(st: &mut RouterState) {
        for p in &mut st.parked {
            *p = false;
        }
    }

    fn bill(&self, st: &mut RouterState, session: usize) {
        st.bills[session] += 1;
        st.agg += if self.bug == Bug::DoubleCountAggregate { 2 } else { 1 };
    }

    /// Consume one reply off the wire front — `Router::route_reply`.
    fn route_front(&self, st: &mut RouterState, driver: usize) -> Result<(), String> {
        let Some(r) = st.wire.pop_front() else {
            return Err("driver stepped with an empty wire".to_string());
        };
        if st.routed[r] {
            return Err(format!("reply {r} consumed twice"));
        }
        st.routed[r] = true;
        let seq = self.scenario.replies[r].seq;
        if let Some(slot) = st.open.get_mut(&seq) {
            // live round: count into the slot, bill the issuer
            slot.got += 1;
            let owner = slot.owner;
            self.bill(st, owner);
        } else if let Some(inf) = st.inflight.get_mut(&seq) {
            // straggler from a timed-out round: billed to the issuer
            // if its session is still open, else dropped
            let owner = inf.owner;
            inf.outstanding -= 1;
            if inf.outstanding == 0 {
                st.inflight.remove(&seq);
            }
            if self.bug == Bug::BillDrainerOnStraggler {
                self.bill(st, driver);
            } else if st.closed[owner] {
                st.dropped += 1;
            } else {
                self.bill(st, owner);
            }
        } else {
            // no record at all (aged out): floor
            st.dropped += 1;
        }
        Self::unpark_all(st);
        Ok(())
    }

    /// Remove a full slot and hand the replies to the session.
    fn collect(&self, st: &mut RouterState, seq: u64, session: usize) {
        st.open.remove(&seq);
        st.retired.push(seq);
        st.pc[session] += 1;
        if self.bug != Bug::MissedWakeup {
            Self::unpark_all(st);
        }
    }

    fn session_step(&self, st: &mut RouterState, s: usize) -> Result<(), String> {
        let script = &self.scenario.sessions[s];
        match script.ops[st.pc[s]].clone() {
            Op::Submit { seq, expected } => {
                st.open.insert(seq, MSlot { owner: s, expected, got: 0 });
                for (r, spec) in self.scenario.replies.iter().enumerate() {
                    if spec.seq == seq {
                        st.armed[r] = true;
                    }
                }
                st.pc[s] += 1;
            }
            Op::Complete { seq } => {
                let full = match st.open.get(&seq) {
                    Some(slot) => slot.got >= slot.expected,
                    None => return Err(format!("session {s}: completing a missing slot {seq}")),
                };
                if st.rx_held == Some(s) {
                    if full {
                        st.rx_held = None; // release the receiver, then collect
                        self.collect(st, seq, s);
                    } else {
                        self.route_front(st, s)?; // drive: route one reply
                    }
                } else if full {
                    self.collect(st, seq, s);
                } else if st.rx_held.is_none() {
                    st.rx_held = Some(s); // become the driver
                } else {
                    st.parked[s] = true; // wait for the driver's notify
                }
            }
            Op::Timeout { seq, aged } => {
                let Some(slot) = st.open.remove(&seq) else {
                    return Err(format!("session {s}: timing out a missing slot {seq}"));
                };
                st.retired.push(seq);
                if slot.got < slot.expected && !aged {
                    st.inflight.insert(
                        seq,
                        MInflight { owner: s, outstanding: slot.expected - slot.got },
                    );
                }
                st.pc[s] += 1;
                Self::unpark_all(st); // retire_ticket notifies
            }
            Op::Close => {
                st.closed[s] = true;
                st.pc[s] += 1;
            }
        }
        Ok(())
    }
}

impl Model for RouterModel {
    type State = RouterState;

    fn threads(&self) -> usize {
        self.session_count() + self.scenario.replies.len()
    }

    fn init(&self) -> RouterState {
        let s = self.session_count();
        let r = self.scenario.replies.len();
        RouterState {
            wire: VecDeque::new(),
            armed: vec![false; r],
            injected: vec![false; r],
            routed: vec![false; r],
            open: BTreeMap::new(),
            inflight: BTreeMap::new(),
            retired: Vec::new(),
            rx_held: None,
            parked: vec![false; s],
            closed: vec![false; s],
            bills: vec![0; s],
            agg: 0,
            dropped: 0,
            pc: vec![0; s],
        }
    }

    fn enabled(&self, st: &RouterState, tid: usize) -> bool {
        let s_count = self.session_count();
        if tid >= s_count {
            // injector: deliverable once armed; stragglers only after
            // their round was retired
            let r = tid - s_count;
            let spec = &self.scenario.replies[r];
            return st.armed[r]
                && !st.injected[r]
                && (!spec.late || st.retired.contains(&spec.seq));
        }
        if st.parked[tid] {
            return false; // on the condvar, needs a notify
        }
        if let Some(Op::Complete { seq }) = self.scenario.sessions[tid].ops.get(st.pc[tid]) {
            if st.rx_held == Some(tid) && st.wire.is_empty() {
                // driver blocked in recv: runnable only once its own
                // slot filled (to release + collect)
                return st.open.get(seq).is_some_and(|slot| slot.got >= slot.expected);
            }
        }
        true
    }

    fn finished(&self, st: &RouterState, tid: usize) -> bool {
        let s_count = self.session_count();
        if tid >= s_count {
            st.injected[tid - s_count]
        } else {
            st.pc[tid] >= self.scenario.sessions[tid].ops.len()
        }
    }

    fn step(&self, st: &mut RouterState, tid: usize) -> Result<(), String> {
        let s_count = self.session_count();
        if tid >= s_count {
            let r = tid - s_count;
            st.injected[r] = true;
            st.wire.push_back(r);
            // a channel send wakes a driver blocked in recv (modeled by
            // `enabled`), but does NOT notify parked sessions
            Ok(())
        } else {
            self.session_step(st, tid)
        }
    }

    fn final_check(&self, st: &RouterState) -> Result<(), String> {
        // Σ session bills == aggregate ledger (closed sessions keep
        // their final bill — mirrors CommStats snapshots at close)
        let sum: u64 = st.bills.iter().sum();
        if sum != st.agg {
            return Err(format!(
                "ledger mismatch: Σ session bills = {sum}, aggregate = {} \
                 (bills {:?}, dropped {})",
                st.agg, st.bills, st.dropped
            ));
        }
        // routed-or-dropped exactly once: every reply was consumed
        // exactly once, or still sits in the channel at shutdown
        for (r, spec) in self.scenario.replies.iter().enumerate() {
            let consumed = st.routed[r];
            let undrained = st.wire.contains(&r);
            if consumed && undrained {
                return Err(format!("reply {r} (seq {}) both routed and on the wire", spec.seq));
            }
            if !consumed && !undrained {
                return Err(format!("reply {r} (seq {}) vanished without routing", spec.seq));
            }
        }
        // schedule-independent bills where the script guarantees one
        for (s, script) in self.scenario.sessions.iter().enumerate() {
            if let Some(exact) = script.exact_bill {
                if st.bills[s] != exact {
                    return Err(format!(
                        "session {s} billed {} responses, script guarantees exactly {exact} \
                         (bills {:?}, aggregate {}, dropped {})",
                        st.bills[s], st.bills, st.agg, st.dropped
                    ));
                }
            }
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------
// Scenarios
// ---------------------------------------------------------------------

/// `n` well-behaved tenants: submit one round of `replies_each`
/// responses, complete it, close. Every bill is schedule-independent.
pub fn normal(n: usize, replies_each: usize) -> Scenario {
    let mut sessions = Vec::new();
    let mut replies = Vec::new();
    for s in 0..n {
        let seq = (s + 1) as u64;
        sessions.push(SessionScript {
            ops: vec![
                Op::Submit { seq, expected: replies_each },
                Op::Complete { seq },
                Op::Close,
            ],
            exact_bill: Some(replies_each as u64),
        });
        for _ in 0..replies_each {
            replies.push(ReplySpec { seq, late: false });
        }
    }
    Scenario { name: "normal", sessions, replies }
}

/// Session 0 times out a 2-reply round (one reply a late straggler) and
/// closes; session 1 runs a normal round and — as the only driver left
/// — drains whatever the wire holds. Depending on the interleaving the
/// straggler is billed to its issuer (record found, session open) or
/// dropped (issuer already closed); session 1's bill must be exactly
/// its own two replies in *every* schedule.
pub fn straggler(aged: bool) -> Scenario {
    Scenario {
        name: if aged { "straggler-aged" } else { "straggler" },
        sessions: vec![
            SessionScript {
                ops: vec![
                    Op::Submit { seq: 1, expected: 2 },
                    Op::Timeout { seq: 1, aged },
                    Op::Close,
                ],
                exact_bill: None, // schedule-dependent: 0, 1 or 2
            },
            SessionScript {
                ops: vec![
                    Op::Submit { seq: 2, expected: 2 },
                    Op::Complete { seq: 2 },
                    Op::Close,
                ],
                exact_bill: Some(2),
            },
        ],
        replies: vec![
            ReplySpec { seq: 1, late: false },
            ReplySpec { seq: 1, late: true },
            ReplySpec { seq: 2, late: false },
            ReplySpec { seq: 2, late: false },
        ],
    }
}

/// A dead worker: session 0's round expects 2 replies but only one
/// exists; the deadline path must terminate cleanly in every schedule
/// and the missing reply must never be billed to anyone.
pub fn dead_worker() -> Scenario {
    Scenario {
        name: "dead-worker",
        sessions: vec![
            SessionScript {
                ops: vec![
                    Op::Submit { seq: 1, expected: 2 },
                    Op::Timeout { seq: 1, aged: false },
                    Op::Close,
                ],
                exact_bill: None, // 0 or 1 (the reply that did arrive)
            },
            SessionScript {
                ops: vec![
                    Op::Submit { seq: 2, expected: 1 },
                    Op::Complete { seq: 2 },
                    Op::Close,
                ],
                exact_bill: Some(1),
            },
        ],
        replies: vec![
            ReplySpec { seq: 1, late: false },
            ReplySpec { seq: 2, late: false },
        ],
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::sched::Explorer;

    /// ISSUE 7 acceptance floor: bounded preemption >= 2 everywhere.
    const BUDGET: usize = 2;

    #[test]
    fn normal_two_tenants_all_schedules_clean() {
        let report = Explorer::new(BUDGET).explore(&RouterModel::new(normal(2, 2)));
        report.assert_clean("normal(2x2)");
        assert!(!report.truncated, "schedule space must be exhausted");
        assert!(report.schedules >= 10, "suspiciously few schedules: {}", report.schedules);
    }

    #[test]
    fn normal_three_tenants_all_schedules_clean() {
        let report = Explorer::new(BUDGET).explore(&RouterModel::new(normal(3, 2)));
        report.assert_clean("normal(3x2)");
        assert!(!report.truncated, "schedule space must be exhausted");
    }

    #[test]
    fn straggler_round_never_double_bills_and_both_outcomes_reachable() {
        let model = RouterModel::new(straggler(false));
        let mut issuer_bills = std::collections::BTreeSet::new();
        let mut saw_drop = false;
        let report = Explorer::new(BUDGET).explore_leaves(&model, &mut |st| {
            issuer_bills.insert(st.bills()[0]);
            saw_drop |= st.dropped() > 0;
        });
        report.assert_clean("straggler");
        assert!(!report.truncated);
        // the enumeration must actually reach both delivery contracts:
        // straggler billed to its (open) issuer, and straggler dropped
        // because the issuer closed first
        assert!(
            issuer_bills.iter().any(|&b| b > 0),
            "no schedule billed the issuer ({issuer_bills:?})"
        );
        assert!(saw_drop, "no schedule dropped a straggler");
    }

    #[test]
    fn aged_straggler_is_dropped_not_billed() {
        let model = RouterModel::new(straggler(true));
        let report = Explorer::new(BUDGET).explore_leaves(&model, &mut |st| {
            // with the record pruned, the late reply can never be
            // billed: the issuer's bill is at most its on-time reply
            assert!(
                st.bills()[0] <= 1,
                "aged straggler was billed (issuer bill {})",
                st.bills()[0]
            );
        });
        report.assert_clean("straggler-aged");
        assert!(!report.truncated);
    }

    #[test]
    fn dead_worker_timeout_path_terminates_everywhere() {
        let report = Explorer::new(BUDGET).explore(&RouterModel::new(dead_worker()));
        report.assert_clean("dead-worker");
        assert!(!report.truncated);
    }

    // ----- seeded bugs: the detectors must actually fire -----

    #[test]
    fn double_count_aggregate_is_caught() {
        let model = RouterModel::with_bug(normal(2, 2), Bug::DoubleCountAggregate);
        let v = Explorer::new(BUDGET)
            .explore(&model)
            .violation
            .expect("double-counted aggregate must be detected");
        assert!(v.message.contains("ledger mismatch"), "{}", v.message);
    }

    #[test]
    fn bill_drainer_on_straggler_is_caught() {
        let model = RouterModel::with_bug(straggler(false), Bug::BillDrainerOnStraggler);
        let v = Explorer::new(BUDGET)
            .explore(&model)
            .violation
            .expect("straggler misattribution must be detected");
        // caught either by the drainer's exact-bill contract or by a
        // ledger mismatch, depending on which schedule hits first
        assert!(
            v.message.contains("guarantees exactly") || v.message.contains("ledger mismatch"),
            "{}",
            v.message
        );
    }

    #[test]
    fn missed_wakeup_deadlocks_and_is_caught() {
        let model = RouterModel::with_bug(normal(2, 2), Bug::MissedWakeup);
        let v = Explorer::new(BUDGET)
            .explore(&model)
            .violation
            .expect("a collect that skips the notify must strand a parked session");
        assert!(v.message.contains("stuck"), "{}", v.message);
    }
}
