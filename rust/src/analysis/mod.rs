//! In-tree concurrency analysis (ISSUE 7): everything the repo uses to
//! *prove things about its own threading*, with zero new dependencies.
//!
//! Three layers, complementing the instrumented sync shim in
//! [`crate::sync`]:
//!
//! * [`sched`] — a loom-style, bounded-preemption schedule explorer
//!   (DFS over interleavings with state-hash memoization). It is the
//!   engine; it knows nothing about the cluster.
//! * [`model`] — miniature, exactly-faithful models of the router /
//!   ticket / billing protocol from `cluster/mod.rs` +
//!   `cluster/session.rs`, run under [`sched`] across *all*
//!   interleavings: every reply is routed-or-dropped exactly once,
//!   Σ session bills == the aggregate ledger, stragglers never
//!   double-bill, aged replies are dropped on the floor, and every
//!   schedule terminates (no lost wakeup in the driver-election
//!   protocol). Seeded-bug variants prove the checks can fail.
//! * [`lint`] — the `dspca lint` repo-invariant scanner (CI hard gate):
//!   line-level rules that keep the invariants the other two layers
//!   verify *enforceable at the source level* (no stats mutation
//!   outside the billing layer, no raw `std::sync` locks outside the
//!   shim, unwrap budgets, flag validation, env hygiene).
//!
//! Division of labor with the existing `propcheck` module: `propcheck`
//! checks *numerical* properties of randomized linear-algebra inputs;
//! `analysis` checks *concurrency* properties of the distributed
//! runtime and *structural* properties of the source tree.

pub mod lint;
pub mod model;
pub mod sched;
