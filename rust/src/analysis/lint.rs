//! `dspca lint` — repo-invariant scanner (line-level, no external
//! parser), the third layer of the ISSUE 7 analysis subsystem.
//!
//! Enforces the conventions the codebase previously kept by discipline:
//!
//! 1. **`commstats-mutation`** — `CommStats` counters are only ever
//!    incremented in `cluster/comm.rs` (merge) and
//!    `cluster/session.rs` (the billing paths). Anywhere else, a
//!    `.field +=` on a stats counter is a second biller that would
//!    silently break the Σ-bills == aggregate invariant the model
//!    checker proves.
//! 2. **`unwrap-budget`** — no `unwrap()`/`expect("...")` in non-test
//!    `src/` beyond an explicit per-file allowlist
//!    ([`UNWRAP_BUDGET`]); the remaining entries are documented
//!    internal-invariant panics. Lock-poisoning unwraps are gone at the
//!    source: the sync shim recovers poison centrally.
//! 3. **`env-set-var`** — `std::env::set_var` only inside the bench
//!    harness (process-global state; everywhere else it is a race with
//!    concurrent tests).
//! 4. **`flag-validation`** — every `cmd_*` handler in `main.rs` calls
//!    `ensure_known_flags` (typo'd flags must error, not silently run
//!    with defaults).
//! 5. **`raw-sync-import`** — no `std::sync::Mutex`/`Condvar` outside
//!    `src/sync/`: every lock goes through the instrumented shim so
//!    the `DSPCA_ANALYZE=1` build sees it.
//! 6. **`obs-confinement`** — the metrics registry's raw mutation
//!    methods are called only inside `src/obs/` (where the
//!    `obs_inc!`/`obs_add!`/`obs_gauge!`/`obs_hist!` macros expand).
//!    Instrumentation sites use the macros, so every metric touch
//!    stays auditable in one module and the disabled-path cost stays
//!    a few relaxed atomics.
//! 7. **`codec-state-mutation`** — the stateful wire-codec stream
//!    fields (`CodecState`'s error-feedback residual and the adaptive
//!    controller's bookkeeping) are only ever assigned in
//!    `cluster/wire.rs` (the codec math) and `cluster/session.rs`
//!    (the per-session lane). A second writer anywhere else would
//!    desynchronize the leader's residual trajectory from the
//!    worker-side `ReplyBank` twin that is rebuilt purely from
//!    request envelopes — the invariant that lets feedback streams
//!    work with no handshake.
//!
//! The scanner strips `//` and `/* */` comments and skips
//! `#[cfg(test)] mod` bodies by brace counting. It is deliberately
//! approximate (a needle inside a string literal counts; a `//` inside
//!  a string truncates the line) — the rules are written so the
//! approximation errs loud on the current tree, and
//! `tests/lint_clean.rs` pins "loud" to zero findings.
//!
//! The needle strings below are assembled with `concat!` so this file
//! does not flag itself.

use std::fmt;
use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

/// One rule violation at a source location.
#[derive(Debug)]
pub struct Finding {
    /// Path relative to `src/`.
    pub file: String,
    /// 1-based line.
    pub line: usize,
    pub rule: &'static str,
    pub message: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "src/{}:{}: [{}] {}", self.file, self.line, self.rule, self.message)
    }
}

// Needles are split so this file never contains its own match targets.
const UNWRAP_NEEDLE: &str = concat!(".unw", "rap()");
const EXPECT_NEEDLE: &str = concat!(".exp", "ect(\"");
const SET_VAR_NEEDLE: &str = concat!("env::set_", "var");
const RAW_MUTEX: &str = concat!("std::sync::", "Mutex");
const RAW_CONDVAR: &str = concat!("std::sync::", "Condvar");
const USE_STD_SYNC: &str = concat!("use std::", "sync::");
const KNOWN_FLAGS_CALL: &str = concat!("ensure_known", "_flags");
const OBS_RAW_NEEDLE: &str = concat!("obs_", "raw_");

/// The `CommStats` counters rule 1 protects.
const COMMSTATS_FIELDS: [&str; 7] = [
    "rounds",
    "matvec_products",
    "vectors_broadcast",
    "vectors_gathered",
    "requests_sent",
    "responses_received",
    "bytes",
];

/// Files allowed to increment `CommStats` fields.
const COMMSTATS_ALLOWED: [&str; 2] = ["cluster/comm.rs", "cluster/session.rs"];

/// The `CodecState` stream fields rule 7 protects (error-feedback
/// residual + adaptive-controller bookkeeping).
const CODEC_STATE_FIELDS: [&str; 5] =
    ["residual", "active_bits", "last_rel", "widenings", "narrowings"];

/// Files allowed to assign codec stream state: the codec math itself
/// and the session lane that drives it.
const CODEC_STATE_ALLOWED: [&str; 2] = ["cluster/wire.rs", "cluster/session.rs"];

/// Files allowed to call `std::env::set_var` (the bench harness owns
/// process-global bench configuration).
const SET_VAR_ALLOWED: [&str; 1] = ["bench_harness/mod.rs"];

/// Per-file budget of panicking `unwrap()`/`expect("...")` calls in
/// non-test code. Every entry is a documented internal-invariant panic
/// (e.g. "slot vanished while the ticket existed", fixed-width slice
/// conversions after an explicit length check). Files not listed have
/// budget 0. Exceeding a budget is a finding — shrink the code, or
/// justify the new panic here in review.
const UNWRAP_BUDGET: &[(&str, usize)] = &[
    ("bench_harness/mod.rs", 1),
    ("cluster/mod.rs", 2),
    ("cluster/session.rs", 1),
    ("cluster/wire.rs", 5),
    ("config/mod.rs", 1),
    ("coordinator/shift_invert.rs", 1),
    ("data/shard.rs", 4),
    ("experiments/lower_bounds.rs", 1),
    ("experiments/transport.rs", 1),
    ("linalg/eigen.rs", 2),
    ("linalg/jacobi.rs", 1),
    ("runtime/pjrt.rs", 2),
    ("transport/inproc.rs", 1),
    ("transport/tcp.rs", 1),
    ("util/json.rs", 1),
    ("util/stats.rs", 1),
];

/// Default lint root: the crate directory this binary was built from
/// (same convention as the bench harness's results root).
pub fn default_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
}

/// Lint `<root>/src`, returning every finding (empty = clean tree).
pub fn run(root: &Path) -> Result<Vec<Finding>> {
    let src = root.join("src");
    let mut files = Vec::new();
    collect_rs_files(&src, &mut files)
        .with_context(|| format!("lint: walking {}", src.display()))?;
    anyhow::ensure!(!files.is_empty(), "lint: no .rs files under {}", src.display());
    let mut findings = Vec::new();
    for path in &files {
        let rel = path
            .strip_prefix(&src)
            .unwrap_or(path)
            .to_string_lossy()
            .replace('\\', "/");
        let text = fs::read_to_string(path)
            .with_context(|| format!("lint: reading {}", path.display()))?;
        scan_file(&rel, &text, &mut findings);
    }
    Ok(findings)
}

fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    let mut entries: Vec<PathBuf> = fs::read_dir(dir)
        .with_context(|| format!("reading dir {}", dir.display()))?
        .map(|e| e.map(|e| e.path()))
        .collect::<std::io::Result<_>>()?;
    entries.sort();
    for path in entries {
        if path.is_dir() {
            collect_rs_files(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Net brace depth change of a code line (comment-stripped). Braces
/// inside string literals are counted too — in practice format strings
/// keep `{`/`}` balanced, and `tests/lint_clean.rs` pins the heuristic
/// against the real tree.
fn brace_delta(code: &str) -> i64 {
    let mut d = 0i64;
    for b in code.bytes() {
        match b {
            b'{' => d += 1,
            b'}' => d -= 1,
            _ => {}
        }
    }
    d
}

/// Drop `//` line comments and `/* */` block comments (tracking block
/// state across lines).
fn strip_comments(line: &str, in_block: &mut bool) -> String {
    let bytes = line.as_bytes();
    let mut out = Vec::with_capacity(bytes.len());
    let mut i = 0;
    while i < bytes.len() {
        if *in_block {
            if bytes[i] == b'*' && bytes.get(i + 1) == Some(&b'/') {
                *in_block = false;
                i += 2;
            } else {
                i += 1;
            }
        } else if bytes[i] == b'/' && bytes.get(i + 1) == Some(&b'/') {
            break; // line (or doc) comment: ignore the rest
        } else if bytes[i] == b'/' && bytes.get(i + 1) == Some(&b'*') {
            *in_block = true;
            i += 2;
        } else {
            out.push(bytes[i]);
            i += 1;
        }
    }
    String::from_utf8_lossy(&out).into_owned()
}

fn count_occurrences(hay: &str, needle: &str) -> usize {
    hay.match_indices(needle).count()
}

/// Scan one file's source text. Separated from [`run`] so tests can
/// feed synthetic sources.
pub fn scan_file(rel: &str, text: &str, findings: &mut Vec<Finding>) {
    let in_sync_module = rel.starts_with("sync/") || rel == "sync.rs";
    let unwrap_budget = UNWRAP_BUDGET
        .iter()
        .find(|(f, _)| *f == rel)
        .map_or(0, |&(_, n)| n);
    let mut unwrap_lines: Vec<usize> = Vec::new();

    // cmd_* tracking (rule 4), active only in main.rs
    struct CmdFn {
        name: String,
        line: usize,
        depth: i64,
        body_started: bool,
        validated: bool,
    }
    let mut current_cmd: Option<CmdFn> = None;

    let mut in_block_comment = false;
    // Some(depth) while inside a `#[cfg(test)] mod` (or any cfg(test)
    // braced item); depth is the running brace balance
    let mut skip: Option<i64> = None;
    let mut pending_test_cfg = false;

    for (idx, raw) in text.lines().enumerate() {
        let line_no = idx + 1;
        let code = strip_comments(raw, &mut in_block_comment);
        let trimmed = code.trim();

        if let Some(depth) = &mut skip {
            *depth += brace_delta(&code);
            if *depth <= 0 {
                skip = None;
            }
            continue;
        }

        if trimmed.starts_with("#[") && trimmed.contains("cfg(") && trimmed.contains("test") {
            pending_test_cfg = true;
            continue;
        }
        if pending_test_cfg {
            if trimmed.starts_with("#[") || trimmed.is_empty() {
                continue; // stacked attributes
            }
            pending_test_cfg = false;
            let delta = brace_delta(&code);
            if delta > 0 {
                // braced item under cfg(test): skip to its closing brace
                skip = Some(delta);
                continue;
            }
            // single-line item (e.g. `#[cfg(test)] use ...;`): fall
            // through and lint it like anything else
        }

        // ---- rule 4: flag validation (main.rs only) ----
        if rel == "main.rs" {
            if let Some(cmd) = &mut current_cmd {
                if code.contains(KNOWN_FLAGS_CALL) {
                    cmd.validated = true;
                }
                cmd.depth += brace_delta(&code);
                if cmd.depth > 0 {
                    cmd.body_started = true;
                }
                if cmd.body_started && cmd.depth <= 0 {
                    if !cmd.validated {
                        findings.push(Finding {
                            file: rel.to_string(),
                            line: cmd.line,
                            rule: "flag-validation",
                            message: format!(
                                "{} does not call {KNOWN_FLAGS_CALL}: unknown flags \
                                 would silently run with defaults",
                                cmd.name
                            ),
                        });
                    }
                    current_cmd = None;
                }
            } else if let Some(pos) = code.find("fn cmd_") {
                let rest = &code[pos + 3..];
                let name: String =
                    rest.chars().take_while(|c| c.is_alphanumeric() || *c == '_').collect();
                let depth = brace_delta(&code);
                current_cmd = Some(CmdFn {
                    name,
                    line: line_no,
                    depth,
                    body_started: depth > 0,
                    validated: code.contains(KNOWN_FLAGS_CALL),
                });
            }
        }

        // ---- rule 1: CommStats mutation containment ----
        if !COMMSTATS_ALLOWED.contains(&rel) {
            for field in COMMSTATS_FIELDS {
                let needle = format!(".{field} +=");
                if code.contains(&needle) {
                    findings.push(Finding {
                        file: rel.to_string(),
                        line: line_no,
                        rule: "commstats-mutation",
                        message: format!(
                            "CommStats counter `{field}` incremented outside {}: \
                             billing must stay in the session layer so \
                             Σ session bills == aggregate holds",
                            COMMSTATS_ALLOWED.join(", ")
                        ),
                    });
                }
            }
        }

        // ---- rule 7: codec stream-state mutation containment ----
        if !CODEC_STATE_ALLOWED.contains(&rel) {
            for field in CODEC_STATE_FIELDS {
                // `.field = ` and `.field += ` (the trailing space keeps
                // `==` comparisons out); method-based mutation is not
                // chased — the rule pins the convention, tests/lint_clean
                // pins the heuristic against the real tree
                let assigned = code.contains(&format!(".{field} = "))
                    || code.contains(&format!(".{field} += "));
                if assigned {
                    findings.push(Finding {
                        file: rel.to_string(),
                        line: line_no,
                        rule: "codec-state-mutation",
                        message: format!(
                            "codec stream state `{field}` assigned outside {}: \
                             a second writer desynchronizes the leader residual \
                             trajectory from the worker-side ReplyBank twin",
                            CODEC_STATE_ALLOWED.join(", ")
                        ),
                    });
                }
            }
        }

        // ---- rule 2: unwrap/expect budget ----
        let panics =
            count_occurrences(&code, UNWRAP_NEEDLE) + count_occurrences(&code, EXPECT_NEEDLE);
        for _ in 0..panics {
            unwrap_lines.push(line_no);
        }

        // ---- rule 3: env::set_var containment ----
        if code.contains(SET_VAR_NEEDLE) && !SET_VAR_ALLOWED.contains(&rel) {
            findings.push(Finding {
                file: rel.to_string(),
                line: line_no,
                rule: "env-set-var",
                message: "process-global env mutation outside the bench harness races \
                          with concurrent tests"
                    .to_string(),
            });
        }

        // ---- rule 6: obs metric-mutation confinement ----
        if code.contains(OBS_RAW_NEEDLE) && !rel.starts_with("obs/") {
            findings.push(Finding {
                file: rel.to_string(),
                line: line_no,
                rule: "obs-confinement",
                message: format!(
                    "direct `{OBS_RAW_NEEDLE}*` metric mutation outside src/obs/: \
                     instrumentation sites must go through the obs_inc!/obs_add!/\
                     obs_gauge!/obs_hist! macros"
                ),
            });
        }

        // ---- rule 5: raw std::sync lock types ----
        if !in_sync_module {
            let qualified = code.contains(RAW_MUTEX) || code.contains(RAW_CONDVAR);
            let imported = code.contains(USE_STD_SYNC)
                && (code.contains("Mutex") || code.contains("Condvar"));
            if qualified || imported {
                findings.push(Finding {
                    file: rel.to_string(),
                    line: line_no,
                    rule: "raw-sync-import",
                    message: "lock types must come from crate::sync (the instrumented \
                              shim), not std::sync"
                        .to_string(),
                });
            }
        }
    }

    if unwrap_lines.len() > unwrap_budget {
        let first_over = unwrap_lines[unwrap_budget];
        findings.push(Finding {
            file: rel.to_string(),
            line: first_over,
            rule: "unwrap-budget",
            message: format!(
                "{} panicking unwrap/expect call(s) in non-test code, budget is \
                 {unwrap_budget} (lines {:?}); return anyhow errors or extend \
                 UNWRAP_BUDGET with justification",
                unwrap_lines.len(),
                unwrap_lines
            ),
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scan(rel: &str, text: &str) -> Vec<Finding> {
        let mut f = Vec::new();
        scan_file(rel, text, &mut f);
        f
    }

    // Synthetic sources build their needles by string concat so this
    // test module stays invisible to the scanner's own pass over the
    // real tree (it skips cfg(test) mods anyway — belt and braces).
    fn unwrap_call() -> String {
        format!("let x = y{};\n", concat!(".unw", "rap()"))
    }

    #[test]
    fn unwrap_over_budget_is_flagged_and_test_mods_are_skipped() {
        let src = format!(
            "fn live() {{\n    {u}}}\n\n#[cfg(test)]\nmod tests {{\n    fn t() {{\n        {u}        {u}    }}\n}}\n",
            u = unwrap_call()
        );
        // "config/mod.rs" has budget 1: the single live call passes …
        assert!(scan("config/mod.rs", &src).is_empty());
        // … but an unbudgeted file flags it, counting only the live one
        let f = scan("linalg/threads.rs", &src);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, "unwrap-budget");
        assert!(f[0].message.contains("budget is 0"));
        assert_eq!(f[0].line, 2);
    }

    #[test]
    fn expect_with_string_counts_but_byte_expect_does_not() {
        let with_str = format!("v{}fail\");\n", concat!(".exp", "ect(\""));
        let f = scan("util/vec.rs", &with_str);
        assert_eq!(f.len(), 1);
        // the JSON scanner's self.expect(b'x') method is not a panic;
        // the synthetic source keeps its braces balanced because the
        // scanner counts braces inside string literals too
        let byte_call = format!("fn f() {{\n    self{}b'x')?;\n}}\n", concat!(".exp", "ect("));
        assert!(scan("util/vec.rs", &byte_call).is_empty());
    }

    #[test]
    fn comments_do_not_count() {
        let src = format!(
            "// doc says {u}fine\n/* block {u}\nstill comment {u} */\nfn f() {{}}\n",
            u = unwrap_call()
        );
        assert!(scan("serve/mod.rs", &src).is_empty());
    }

    #[test]
    fn commstats_mutation_outside_session_layer_is_flagged() {
        let src = "fn f(st: &mut CommStats) {\n    st.responses_received += 1;\n}\n";
        let f = scan("cluster/mod.rs", src);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, "commstats-mutation");
        assert_eq!(f[0].line, 2);
        // the billing layer itself is allowed
        assert!(scan("cluster/session.rs", src).is_empty());
        assert!(scan("cluster/comm.rs", src).is_empty());
    }

    #[test]
    fn codec_state_mutation_outside_the_codec_layer_is_flagged() {
        let src = "fn f(st: &mut CodecState) {\n    st.residual = Vec::new();\n    st.widenings += 1;\n}\n";
        let f = scan("coordinator/quantized.rs", src);
        assert_eq!(f.len(), 2, "{f:?}");
        assert!(f.iter().all(|x| x.rule == "codec-state-mutation"));
        assert_eq!((f[0].line, f[1].line), (2, 3));
        // the codec math and the session lane are the two legal writers
        assert!(scan("cluster/wire.rs", src).is_empty());
        assert!(scan("cluster/session.rs", src).is_empty());
        // comparisons and method calls are not assignments
        let ok = "fn g(st: &CodecState) {\n    if st.last_rel == 0.0 { st.residual.len(); }\n}\n";
        assert!(scan("coordinator/quantized.rs", ok).is_empty());
    }

    #[test]
    fn raw_sync_imports_are_flagged_outside_the_shim() {
        let qualified = format!("let m = {}::new(0);\n", concat!("std::sync::", "Mutex"));
        let f = scan("cluster/mod.rs", &qualified);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, "raw-sync-import");
        let braced = format!("{}{{Arc, Mutex}};\n", concat!("use std::", "sync::"));
        assert_eq!(scan("serve/mod.rs", &braced).len(), 1);
        // mpsc/Arc imports and the shim itself are fine
        let ok = format!("{}{{mpsc, Arc}};\n", concat!("use std::", "sync::"));
        assert!(scan("serve/mod.rs", &ok).is_empty());
        assert!(scan("sync/analyze.rs", &qualified).is_empty());
    }

    #[test]
    fn set_var_is_only_allowed_in_the_bench_harness() {
        let src = format!("std::{}(\"X\", \"1\");\n", concat!("env::set_", "var"));
        assert_eq!(scan("experiments/mod.rs", &src)[0].rule, "env-set-var");
        assert!(scan("bench_harness/mod.rs", &src).is_empty());
    }

    #[test]
    fn cmd_fn_without_flag_validation_is_flagged() {
        let bad = "fn cmd_bad(args: &Args) -> Result<()> {\n    Ok(())\n}\n";
        let f = scan("main.rs", bad);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, "flag-validation");
        assert!(f[0].message.contains("cmd_bad"));
        let good = format!(
            "fn cmd_good(args: &Args) -> Result<()> {{\n    args.{}(\"good\", &[])?;\n    Ok(())\n}}\n",
            concat!("ensure_known", "_flags")
        );
        assert!(scan("main.rs", &good).is_empty());
        // the rule only applies to main.rs
        assert!(scan("experiments/mod.rs", bad).is_empty());
    }

    #[test]
    fn raw_metric_mutation_is_confined_to_the_obs_module() {
        let src = format!(
            "fn f() {{\n    M.{}add(1);\n}}\n",
            concat!("obs_", "raw_")
        );
        let f = scan("cluster/session.rs", &src);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, "obs-confinement");
        assert_eq!(f[0].line, 2);
        assert!(f[0].message.contains("obs_inc!"));
        // the macro definitions themselves live under src/obs/
        assert!(scan("obs/metrics.rs", &src).is_empty());
        assert!(scan("obs/trace.rs", &src).is_empty());
        // macro call sites are clean by construction
        let ok = "fn g() {\n    crate::obs_inc!(CLUSTER_SUBMITS_TOTAL);\n}\n";
        assert!(scan("cluster/session.rs", ok).is_empty());
    }

    #[test]
    fn cfg_test_blocks_with_nested_braces_are_fully_skipped() {
        let src = format!(
            "#[cfg(all(test, dspca_analyze))]\nmod tests {{\n    mod inner {{\n        fn f() {{ {u}    }}\n    }}\n}}\nfn live() {{ {u}}}\n",
            u = unwrap_call()
        );
        let f = scan("analysis/sched.rs", &src);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].line, 8, "only the live unwrap counts");
    }

    #[test]
    fn the_real_tree_is_clean() {
        // the library-level equivalent of tests/lint_clean.rs, so a
        // plain `cargo test` catches regressions without the
        // integration-test binary
        let findings = run(&default_root()).expect("lint walk failed");
        assert!(
            findings.is_empty(),
            "dspca lint found {} issue(s):\n{}",
            findings.len(),
            findings.iter().map(|f| f.to_string()).collect::<Vec<_>>().join("\n")
        );
    }
}
