//! # dspca — Communication-efficient Distributed Stochastic PCA
//!
//! Reproduction of *"Communication-efficient Algorithms for Distributed
//! Stochastic Principal Component Analysis"* (Garber, Shamir, Srebro;
//! ICML 2017) as a three-layer Rust + JAX + Pallas system.
//!
//! The crate is organised bottom-up:
//!
//! - [`linalg`] — dense linear algebra substrate (gemm, QR, symmetric
//!   eigensolvers, PSD matrix functions). No external BLAS/LAPACK.
//! - [`rng`] — deterministic PCG64 RNG + gaussian sampling (no `rand`).
//! - [`data`] — the paper's synthetic distributions (§5 covariance model,
//!   Thm 3 / Thm 5 lower-bound constructions) and data shards.
//! - [`cluster`] — simulated m-machine cluster: worker threads owning
//!   shards, typed messages, and exact communication-round accounting —
//!   including the multi-vector **block protocol**
//!   ([`cluster::Session::dist_matmat`]: one round, one message per live
//!   worker, `k` vectors of traffic) that the top-`k` family rides, and
//!   the **wire layer** ([`cluster::WireCodec`]): every payload is
//!   shipped through a configurable codec (lossless f64 / f32 / bf16)
//!   and `CommStats.bytes` is billed from the encoded frames themselves.
//!   The cluster is **multi-tenant** and its collectives are
//!   **split-phase**: it is `Sync`; all billing, codec state and
//!   collectives live on the per-tenant [`cluster::Session`]
//!   ([`cluster::Cluster::session`]); and every collective is
//!   submit ([`cluster::Session::submit`] → [`cluster::Ticket`]) +
//!   complete, with a reply router delivering every response by its
//!   echoed sequence number — so concurrent tenants' rounds (and one
//!   algorithm's independent rounds, via
//!   [`cluster::Session::dist_matvec_submit`] /
//!   [`cluster::Session::dist_matmat_submit`]) overlap on the wire
//!   while bills stay exactly solo-run bills and sum to the cluster's
//!   aggregate.
//! - [`coordinator`] — the paper's algorithms: one-shot averaging
//!   estimators (Thm 3/4/5), distributed power method / Lanczos,
//!   hot-potato Oja SGD, Shift-and-Invert with locally-preconditioned
//!   linear-system solvers (Alg 1 + Alg 2, Thm 6), and the Theorem-7
//!   top-`k` subspace family (block power, block Lanczos, batched
//!   deflated S&I) on the block protocol. All written against the
//!   session view, so any mix of them runs concurrently on one cluster.
//! - [`transport`] — the pluggable message substrate under the cluster:
//!   a `Transport` trait with an in-proc (`mpsc`) backend and a real
//!   TCP backend (`std::net`, length-prefixed whole-message frames
//!   carrying the materialized wire-codec output). A leader process can
//!   drive N `dspca worker --listen <addr>` processes; bills are
//!   backend-invariant (E12, `dspca transport`).
//! - [`serve`] — the multi-tenant scheduler: a FIFO job queue drained by
//!   N concurrent leader threads over one shared cluster, with per-job
//!   bills (identical to solo-run bills, verified) and batch
//!   throughput/latency metrics. Surfaced as `dspca serve` (E11).
//! - [`runtime`] — PJRT bridge: loads AOT-compiled HLO artifacts produced
//!   by `python/compile/aot.py` and runs them from the worker hot path
//!   (behind the `pjrt` cargo feature; the default build uses a stub).
//! - [`experiments`] — drivers regenerating every table and figure in the
//!   paper's evaluation (see `DESIGN.md` §4 for the experiment index).
//! - [`sync`] — instrumented synchronization shim: every lock/condvar in
//!   the crate goes through it. Transparent over `std::sync` in normal
//!   builds; under `DSPCA_ANALYZE=1` it becomes a lockdep (lock-order
//!   cycle detection, fail-fast with the witness chain) plus a
//!   no-locks-across-transport-I/O checker.
//! - [`analysis`] — the in-tree concurrency analyzer: a
//!   bounded-preemption schedule explorer ([`analysis::sched`]), model
//!   checks of the router/ticket/billing protocol across all
//!   interleavings ([`analysis::model`]), and the `dspca lint`
//!   repo-invariant gate ([`analysis::lint`]).
//! - [`obs`] — the flight recorder (DESIGN.md §12): an always-on
//!   metrics registry over relaxed atomics plus opt-in JSONL event
//!   tracing (`DSPCA_TRACE` / `--trace`) whose byte events are emitted
//!   at the billing sites, making Σ traced bytes per session a second,
//!   independently-plumbed copy of that session's `CommStats` bill —
//!   rendered by `dspca stats` / `dspca trace-report` and exportable
//!   to `chrome://tracing`.
//! - [`util`], [`propcheck`], [`bench_harness`] — JSON/CSV/stats,
//!   property-testing and benchmarking substrates (offline image has no
//!   serde/proptest/criterion).
//!
//! ## Quickstart
//!
//! ```no_run
//! use dspca::prelude::*;
//!
//! let dist = CovModel::paper_fig1(300, 7).gaussian();
//! let cluster = Cluster::generate(&dist, 25, 400, 42).unwrap();
//! // one tenant session per query; sessions bill independently
//! let est = SignFixedAverage.run(&cluster.session()).unwrap();
//! println!("error = {:.3e}, rounds = {}", est.error(dist.v1()), est.comm.rounds);
//! ```
//!
//! Many queries, one cluster (see `examples/serve.rs` for the full
//! two-tenant demo):
//!
//! ```no_run
//! use dspca::prelude::*;
//! use dspca::serve::{serve, Job};
//!
//! let dist = CovModel::paper_fig1(60, 7).gaussian();
//! let cluster = Cluster::generate(&dist, 8, 400, 42).unwrap();
//! let jobs = vec![
//!     Job::new("lossless", Box::new(DistributedPower::default())),
//!     Job::new("bf16", Box::new(QuantizedPower::new(WirePrecision::Bf16))),
//! ];
//! let report = serve(&cluster, jobs, 2).unwrap();
//! for j in &report.jobs {
//!     println!("{}: rounds={} bytes={}", j.name, j.comm.rounds, j.comm.bytes);
//! }
//! ```

pub mod analysis;
pub mod bench_harness;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod linalg;
pub mod obs;
pub mod propcheck;
pub mod rng;
pub mod runtime;
pub mod serve;
pub mod sync;
pub mod transport;
pub mod util;

/// Convenience re-exports covering the public API surface used by the
/// examples and benches.
pub mod prelude {
    pub use crate::cluster::{
        Cluster, CommStats, MatmatTicket, MatvecTicket, OracleSpec, Session, Ticket, WireCodec,
        WirePrecision,
    };
    pub use crate::coordinator::{
        Algorithm, BlockLanczos, CentralizedErm, CentralizedSubspace, DeflatedShiftInvert,
        DistributedLanczos, DistributedOrthoIteration, DistributedPower, Estimate, HotPotatoOja,
        NaiveAverage, ProjectionAverage, QuantizedPower, ShiftInvert, SignFixedAverage, SniConfig,
        SubspaceEstimate, SubspaceProjectionAverage,
    };
    pub use crate::data::{CovModel, Distribution, Thm3Dist, Thm5Dist};
    pub use crate::linalg::Matrix;
    pub use crate::rng::Pcg64;
    pub use crate::transport::TransportSpec;
}
