//! `dspca` launcher: regenerate any of the paper's experiments from the
//! command line, or serve a multi-tenant query batch.
//!
//! ```text
//! dspca figure1   [--dist gaussian|uniform] [--d 300] [--m 25]
//!                 [--n-list 25,50,...] [--runs 40] [--out results/]
//!                 [--transport inproc|tcp] [--workers a:p,b:p,...]
//!                 [--io-timeout-secs 20] [--threads 4]
//! dspca table1    [--d 300] [--m 25] [--n 400] [--runs 12]
//! dspca lower-bounds [--runs 60]
//! dspca scaling   [--n-sweep | --m-sweep]
//! dspca topk      [--d 60] [--m 8] [--n 400] [--k-list 1,2,4,8] [--runs 8]
//!                 [--threads 4] [--density 0.05]
//! dspca wire      [--d 60] [--m 8] [--n 400] [--runs 8]
//!                 [--codec f64|f32|bf16|q8|q4|tops] [--feedback]
//!                 [--adaptive] [--transport inproc|tcp]
//!                 [--workers a:p,b:p,...] [--io-timeout-secs 20]
//! dspca serve     [--d 60] [--m 8] [--n 400] [--jobs 12] [--tenants 1,2,4,8]
//!                 [--transport inproc|tcp] [--workers a:p,b:p,...]
//!                 [--io-timeout-secs 20] [--no-overlap-assert] [--threads 4]
//!                 [--fusion] [--trace [path]]
//! dspca transport [--d-list 16,64,256] [--m 4] [--n 200] [--rounds 32]
//!                 [--io-timeout-secs 20] [--no-pipeline-assert]
//!                 [--density 0.05] [--reactor]
//! dspca worker    [--listen 127.0.0.1:7070] [--once] [--io-timeout-secs 20]
//!                 [--threads 4]
//! dspca bench-check [--files BENCH_linalg.json,...,BENCH_obs.json]
//! dspca e2e       [--artifacts artifacts/] [--m 4] [--n 400] [--d 64]
//! dspca selftest
//! dspca lint      [--root path/to/crate]
//! dspca stats     [--json]
//! dspca trace-report --file results/trace.jsonl [--chrome out.json]
//! ```
//!
//! **Observability**: `DSPCA_TRACE=<path>` (any command) or `--trace
//! [path]` (serve; bare flag defaults to `<out>/trace.jsonl`) streams
//! timestamped JSONL events — one per collective submit/reply/bill,
//! fusion flush, scheduler reject — to the named file. `dspca
//! trace-report --file <path>` renders per-tenant round timelines and
//! cross-checks Σ traced bytes against each session's bill; `--chrome
//! <out>` additionally writes a `chrome://tracing` / Perfetto-loadable
//! export. `dspca stats` drives a small fused workload and prints the
//! process metrics snapshot (counters/gauges/histograms; `--json` for
//! machine-readable form).
//!
//! `--threads N` sets the process-global compute-thread budget the
//! blocked GEMM and shard covariance kernels use (`DSPCA_THREADS` is the
//! env equivalent; default 1 = the exact scalar kernels). It changes
//! wall clock only — rounds/messages/bytes are kernel-invariant.
//! `--density rho` swaps the gaussian §5 model for the sparse
//! axis-aligned one; shards become CSR end to end.
//!
//! `dspca worker --listen <addr>` turns this binary into one remote
//! machine of the paper's cluster: it waits for a leader, receives its
//! shard over the handshake, and answers collective requests over TCP.
//! Any leader subcommand that accepts `--transport tcp --workers ...`
//! then runs the cluster multi-process (see README for the two-terminal
//! quickstart). Unknown or typo'd flags are an error listing the
//! subcommand's accepted flags (`--n-lsit 25` no longer runs silently
//! with defaults).

use anyhow::{bail, Context, Result};

use dspca::cluster::OracleSpec;
use dspca::config::Args;
use dspca::experiments::{
    figure1, lower_bounds, scaling, serve as serve_exp, table1, topk,
    transport as transport_exp, wire,
};
use dspca::transport::TransportSpec;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args = Args::from_env()?;
    let out_dir = args.get("out").unwrap_or("results").to_string();
    let trace_path = trace_target(&args, &out_dir);
    if let Some(path) = &trace_path {
        if let Some(dir) = std::path::Path::new(path).parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)
                    .with_context(|| format!("trace: cannot create {}", dir.display()))?;
            }
        }
        dspca::obs::trace::install_file(path)?;
    }
    let result = match args.command.as_deref() {
        Some("figure1") => cmd_figure1(&args, &out_dir),
        Some("table1") => cmd_table1(&args, &out_dir),
        Some("lower-bounds") => cmd_lower_bounds(&args, &out_dir),
        Some("scaling") => cmd_scaling(&args, &out_dir),
        Some("topk") => cmd_topk(&args, &out_dir),
        Some("wire") => cmd_wire(&args, &out_dir),
        Some("serve") => cmd_serve(&args, &out_dir),
        Some("transport") => cmd_transport(&args, &out_dir),
        Some("worker") => cmd_worker(&args),
        Some("bench-check") => cmd_bench_check(&args),
        Some("e2e") => cmd_e2e(&args),
        Some("selftest") => cmd_selftest(&args),
        Some("lint") => cmd_lint(&args),
        Some("stats") => cmd_stats(&args),
        Some("trace-report") => cmd_trace_report(&args),
        Some(other) => bail!("unknown command '{other}' (try: figure1, table1, lower-bounds, scaling, topk, wire, serve, transport, worker, bench-check, e2e, selftest, lint, stats, trace-report)"),
        None => {
            println!(
                "dspca — Communication-efficient Distributed Stochastic PCA\n\
                 commands: figure1 | table1 | lower-bounds | scaling | topk | wire | serve | transport | worker | bench-check | e2e | selftest | lint | stats | trace-report\n\
                 see README.md for flags"
            );
            Ok(())
        }
    };
    if trace_path.is_some() {
        // flush and close the sink whether the command succeeded or not
        // — a failed run's partial trace is exactly when you want it
        let flushed = dspca::obs::trace::finish();
        match (&result, flushed) {
            (_, Err(e)) if result.is_ok() => return Err(e.context("trace: flushing sink")),
            _ => {}
        }
        if let Some(path) = &trace_path {
            eprintln!("trace written to {path}");
        }
    }
    result
}

/// Resolve the trace destination: `--trace <path>` wins, bare `--trace`
/// means `<out>/trace.jsonl`, else the `DSPCA_TRACE` env var (any
/// command), else tracing stays off.
fn trace_target(args: &Args, out_dir: &str) -> Option<String> {
    match args.get("trace") {
        Some("true") => Some(format!("{out_dir}/trace.jsonl")),
        Some(path) => Some(path.to_string()),
        None => match std::env::var("DSPCA_TRACE") {
            Ok(p) if !p.is_empty() => Some(p),
            _ => None,
        },
    }
}

/// Apply `--threads N` (N >= 1) to the process-global compute-thread
/// budget. Absent flag leaves the `DSPCA_THREADS`/default resolution
/// alone; `--threads 0` is an error rather than a silent no-op.
fn threads_from(args: &Args) -> Result<()> {
    if let Some(v) = args.get("threads") {
        let t = v
            .parse::<usize>()
            .map_err(|e| anyhow::anyhow!("--threads {v}: not a whole number ({e})"))?;
        anyhow::ensure!(t >= 1, "--threads must be >= 1");
        dspca::linalg::set_compute_threads(t);
    }
    Ok(())
}

/// Parse `--density rho` into the sparse-workload option (`None` =
/// dense gaussian model). Out-of-range values are a hard error.
fn density_from(args: &Args) -> Result<Option<f64>> {
    match args.get("density") {
        None => Ok(None),
        Some(_) => {
            let rho = args.get_f64("density", 1.0)?;
            anyhow::ensure!(
                rho > 0.0 && rho <= 1.0,
                "--density must be in (0, 1], got {rho}"
            );
            Ok(Some(rho))
        }
    }
}

fn oracle_from(args: &Args) -> OracleSpec {
    match args.get("artifacts") {
        Some(dir) => OracleSpec::Pjrt { artifact_dir: dir.to_string() },
        None => OracleSpec::Native,
    }
}

/// Parse `--transport {inproc,tcp}` / `--workers <addr,...>` /
/// `--io-timeout-secs <n>`. A bad combination (tcp without workers,
/// workers or io-timeout under inproc, an unknown backend, an empty
/// list, a zero timeout) is a hard error, never a silent fallback.
fn transport_from(args: &Args) -> Result<TransportSpec> {
    let io_timeout_secs = match args.get("io-timeout-secs") {
        Some(v) => Some(v.parse::<u64>().map_err(|e| {
            anyhow::anyhow!("--io-timeout-secs {v}: not a whole number of seconds ({e})")
        })?),
        None => None,
    };
    TransportSpec::from_flags(args.get("transport"), args.get("workers"), io_timeout_secs)
}

fn cmd_figure1(args: &Args, out_dir: &str) -> Result<()> {
    args.ensure_known_flags(
        "figure1",
        &[
            "dist",
            "d",
            "m",
            "n-list",
            "runs",
            "seed",
            "artifacts",
            "out",
            "transport",
            "workers",
            "io-timeout-secs",
            "threads",
        ],
    )?;
    threads_from(args)?;
    let dist = match args.get("dist").unwrap_or("gaussian") {
        "gaussian" => figure1::Fig1Dist::Gaussian,
        "uniform" => figure1::Fig1Dist::ScaledUniform,
        other => bail!("unknown dist '{other}'"),
    };
    let defaults = figure1::Fig1Config::default();
    let cfg = figure1::Fig1Config {
        d: args.get_usize("d", defaults.d)?,
        m: args.get_usize("m", defaults.m)?,
        n_list: args.get_usize_list("n-list", &defaults.n_list)?,
        runs: args.get_usize("runs", defaults.runs)?,
        seed: args.get_u64("seed", defaults.seed)?,
        dist,
        oracle: oracle_from(args),
        transport: transport_from(args)?,
    };
    let table = figure1::run(&cfg)?;
    let path = format!("{out_dir}/figure1_{:?}.csv", cfg.dist).to_lowercase();
    table.write(&path)?;
    println!("wrote {path}");
    Ok(())
}

fn cmd_table1(args: &Args, out_dir: &str) -> Result<()> {
    args.ensure_known_flags("table1", &["d", "m", "n", "runs", "seed", "artifacts", "out"])?;
    let defaults = table1::Table1Config::default();
    let cfg = table1::Table1Config {
        d: args.get_usize("d", defaults.d)?,
        m: args.get_usize("m", defaults.m)?,
        n: args.get_usize("n", defaults.n)?,
        runs: args.get_usize("runs", defaults.runs)?,
        seed: args.get_u64("seed", defaults.seed)?,
        oracle: oracle_from(args),
    };
    let (rows, table) = table1::run(&cfg)?;
    let dist = dspca::data::CovModel::paper_fig1(cfg.d, cfg.seed ^ 0x7a).gaussian();
    let eps = dspca::data::Distribution::eps_erm(&dist, cfg.m, cfg.n, 0.25);
    println!("{}", table1::render_rows(&rows, eps));
    let path = format!("{out_dir}/table1.csv");
    table.write(&path)?;
    println!("wrote {path}");
    Ok(())
}

fn cmd_lower_bounds(args: &Args, out_dir: &str) -> Result<()> {
    args.ensure_known_flags(
        "lower-bounds",
        &["n-list", "m-list", "runs", "seed", "delta", "out"],
    )?;
    let defaults = lower_bounds::LowerBoundConfig::default();
    let cfg = lower_bounds::LowerBoundConfig {
        n_list: args.get_usize_list("n-list", &defaults.n_list)?,
        m_list: args.get_usize_list("m-list", &defaults.m_list)?,
        runs: args.get_usize("runs", defaults.runs)?,
        seed: args.get_u64("seed", defaults.seed)?,
        delta: args.get_f64("delta", defaults.delta)?,
    };
    let (t3, slopes) = lower_bounds::run_thm3(&cfg)?;
    println!("Thm3 naive-averaging slopes in n (expect ~ -1): {slopes:.2?}");
    t3.write(format!("{out_dir}/thm3_naive.csv"))?;
    let (t5, slope) = lower_bounds::run_thm5(&cfg)?;
    println!("Thm5 sign-fixed slope in n (expect -> -2 as bias dominates): {slope:.2}");
    t5.write(format!("{out_dir}/thm5_signfix.csv"))?;
    println!("wrote {out_dir}/thm3_naive.csv, {out_dir}/thm5_signfix.csv");
    Ok(())
}

fn cmd_scaling(args: &Args, out_dir: &str) -> Result<()> {
    args.ensure_known_flags(
        "scaling",
        &[
            "d",
            "m",
            "n-list",
            "m-list",
            "n",
            "runs",
            "seed",
            "eps",
            "clustered-spectrum",
            "delta",
            "m-sweep",
            "n-sweep",
            "out",
        ],
    )?;
    let defaults = scaling::ScalingConfig::default();
    let cfg = scaling::ScalingConfig {
        d: args.get_usize("d", defaults.d)?,
        m: args.get_usize("m", defaults.m)?,
        n_list: args.get_usize_list("n-list", &defaults.n_list)?,
        m_list: args.get_usize_list("m-list", &defaults.m_list)?,
        n_for_m_sweep: args.get_usize("n", defaults.n_for_m_sweep)?,
        runs: args.get_usize("runs", defaults.runs)?,
        seed: args.get_u64("seed", defaults.seed)?,
        eps: args.get_f64("eps", defaults.eps)?,
        spread_spectrum: !args.get_bool("clustered-spectrum"),
        delta: args.get_f64("delta", defaults.delta)?,
    };
    if !args.get_bool("m-sweep") {
        let t = scaling::run_n_sweep(&cfg)?;
        t.write(format!("{out_dir}/scaling_n.csv"))?;
        println!("wrote {out_dir}/scaling_n.csv");
    }
    if !args.get_bool("n-sweep") {
        let t = scaling::run_m_sweep(&cfg)?;
        t.write(format!("{out_dir}/scaling_m.csv"))?;
        println!("wrote {out_dir}/scaling_m.csv");
    }
    Ok(())
}

fn cmd_topk(args: &Args, out_dir: &str) -> Result<()> {
    args.ensure_known_flags(
        "topk",
        &["d", "m", "n", "k-list", "runs", "seed", "artifacts", "out", "threads", "density"],
    )?;
    threads_from(args)?;
    let defaults = topk::TopkConfig::default();
    let cfg = topk::TopkConfig {
        d: args.get_usize("d", defaults.d)?,
        m: args.get_usize("m", defaults.m)?,
        n: args.get_usize("n", defaults.n)?,
        k_list: args.get_usize_list("k-list", &defaults.k_list)?,
        runs: args.get_usize("runs", defaults.runs)?,
        seed: args.get_u64("seed", defaults.seed)?,
        oracle: oracle_from(args),
        density: density_from(args)?,
    };
    let table = topk::run(&cfg)?;
    let path = format!("{out_dir}/topk.csv");
    table.write(&path)?;
    println!("wrote {path}");
    Ok(())
}

fn cmd_wire(args: &Args, out_dir: &str) -> Result<()> {
    args.ensure_known_flags(
        "wire",
        &[
            "d",
            "m",
            "n",
            "runs",
            "seed",
            "artifacts",
            "out",
            "transport",
            "workers",
            "io-timeout-secs",
            "codec",
            "feedback",
            "adaptive",
        ],
    )?;
    let defaults = wire::WireConfig::default();
    let d = args.get_usize("d", defaults.d)?;
    let cfg = wire::WireConfig {
        d,
        m: args.get_usize("m", defaults.m)?,
        n: args.get_usize("n", defaults.n)?,
        runs: args.get_usize("runs", defaults.runs)?,
        seed: args.get_u64("seed", defaults.seed)?,
        oracle: oracle_from(args),
        transport: transport_from(args)?,
        codec: codec_from(args, d)?,
    };
    let table = wire::run(&cfg)?;
    let path = format!("{out_dir}/wire.csv");
    table.write(&path)?;
    println!("wrote {path}");
    Ok(())
}

/// Parse `--codec {f64,f32,bf16,q8,q4,tops}` (+ `--feedback` /
/// `--adaptive` modifiers) into the single-codec override for the wire
/// sweep. No `--codec` means the full-family sweep; a modifier without
/// `--codec` is a hard error, never a silent no-op. `tops` keeps
/// `s = max(d/8, 1)` coordinates with q8 values.
fn codec_from(args: &Args, d: usize) -> Result<Option<dspca::cluster::WireCodec>> {
    use dspca::cluster::{QuantBits, WireCodec, WirePrecision};
    let (feedback, adaptive) = (args.get_bool("feedback"), args.get_bool("adaptive"));
    let Some(name) = args.get("codec") else {
        anyhow::ensure!(
            !feedback && !adaptive,
            "--feedback/--adaptive modify a single codec: add --codec {{q8,q4,tops}}"
        );
        return Ok(None);
    };
    let mut codec = match name {
        "f64" => WireCodec::lossless(),
        "f32" => WireCodec::new(WirePrecision::F32),
        "bf16" => WireCodec::new(WirePrecision::Bf16),
        "q8" => WireCodec::quant(QuantBits::Q8),
        "q4" => WireCodec::quant(QuantBits::Q4),
        "tops" => WireCodec::top_s((d / 8).max(1) as u32, QuantBits::Q8),
        other => bail!("unknown codec '{other}' (try: f64, f32, bf16, q8, q4, tops)"),
    };
    if feedback {
        codec = codec.with_feedback();
    }
    if adaptive {
        codec = codec.with_adaptive();
    }
    Ok(Some(codec))
}

fn cmd_serve(args: &Args, out_dir: &str) -> Result<()> {
    args.ensure_known_flags(
        "serve",
        &[
            "d",
            "m",
            "n",
            "jobs",
            "tenants",
            "seed",
            "artifacts",
            "out",
            "transport",
            "workers",
            "io-timeout-secs",
            "no-overlap-assert",
            "threads",
            "fusion",
            "trace",
        ],
    )?;
    threads_from(args)?;
    let defaults = serve_exp::ServeConfig::default();
    let cfg = serve_exp::ServeConfig {
        d: args.get_usize("d", defaults.d)?,
        m: args.get_usize("m", defaults.m)?,
        n: args.get_usize("n", defaults.n)?,
        jobs: args.get_usize("jobs", defaults.jobs)?,
        tenants_list: args.get_usize_list("tenants", &defaults.tenants_list)?,
        seed: args.get_u64("seed", defaults.seed)?,
        oracle: oracle_from(args),
        transport: transport_from(args)?,
        // the split-phase acceptance gate is on by default; constrained
        // hosts can opt out explicitly
        assert_overlap: if args.get_bool("no-overlap-assert") {
            None
        } else {
            defaults.assert_overlap
        },
    };
    let table = serve_exp::run(&cfg)?;
    let path = format!("{out_dir}/serve.csv");
    table.write(&path)?;
    println!("wrote {path}");
    // --fusion additionally runs the E11 round-fusion gate (in-proc;
    // bill + counter ensures unconditional, wall-clock ratio gated by
    // DSPCA_STRESS=1 like the overlap gate)
    if args.get_bool("fusion") {
        let fcfg = serve_exp::FusionSweepConfig { seed: cfg.seed, ..Default::default() };
        let table = serve_exp::run_fusion(&fcfg)?;
        let path = format!("{out_dir}/serve_fusion.csv");
        table.write(&path)?;
        println!("wrote {path}");
    }
    Ok(())
}

fn cmd_transport(args: &Args, out_dir: &str) -> Result<()> {
    args.ensure_known_flags(
        "transport",
        &[
            "d-list",
            "m",
            "n",
            "rounds",
            "seed",
            "artifacts",
            "out",
            "io-timeout-secs",
            "no-pipeline-assert",
            "density",
            "reactor",
        ],
    )?;
    let defaults = transport_exp::TransportConfig::default();
    let io_timeout_secs = args.get_u64("io-timeout-secs", defaults.io_timeout.as_secs())?;
    anyhow::ensure!(io_timeout_secs >= 1, "--io-timeout-secs must be >= 1");
    let cfg = transport_exp::TransportConfig {
        d_list: args.get_usize_list("d-list", &defaults.d_list)?,
        m: args.get_usize("m", defaults.m)?,
        n: args.get_usize("n", defaults.n)?,
        rounds: args.get_usize("rounds", defaults.rounds)?,
        seed: args.get_u64("seed", defaults.seed)?,
        oracle: oracle_from(args),
        io_timeout: std::time::Duration::from_secs(io_timeout_secs),
        // the split-phase gate is on by default; constrained hosts can
        // opt out explicitly (parity with serve's --no-overlap-assert)
        assert_pipeline_win: !args.get_bool("no-pipeline-assert"),
        density: density_from(args)?,
    };
    let table = transport_exp::run(&cfg)?;
    let path = format!("{out_dir}/transport.csv");
    table.write(&path)?;
    println!("wrote {path}");
    // --reactor additionally runs the E12 reactor gate: 64 loopback
    // peers, <= 1 leader-side reader thread, bills identical to
    // in-proc (both ensures structural — never wall-clock)
    if args.get_bool("reactor") {
        let rcfg = transport_exp::ReactorConfig {
            seed: cfg.seed,
            io_timeout: cfg.io_timeout,
            ..Default::default()
        };
        let table = transport_exp::run_reactor(&rcfg)?;
        let path = format!("{out_dir}/transport_reactor.csv");
        table.write(&path)?;
        println!("wrote {path}");
    }
    Ok(())
}

fn cmd_worker(args: &Args) -> Result<()> {
    args.ensure_known_flags("worker", &["listen", "once", "io-timeout-secs", "threads"])?;
    threads_from(args)?;
    let addr = args.get("listen").unwrap_or("127.0.0.1:7070");
    let io_timeout_secs = args
        .get_u64("io-timeout-secs", dspca::transport::DEFAULT_IO_TIMEOUT.as_secs())?;
    anyhow::ensure!(io_timeout_secs >= 1, "--io-timeout-secs must be >= 1");
    let listener = std::net::TcpListener::bind(addr)
        .with_context(|| format!("worker: cannot listen on {addr}"))?;
    // the bound address is the first stdout line, so scripts (and the
    // process-level integration test) can use `--listen 127.0.0.1:0`
    // and read the ephemeral port back
    println!("dspca worker listening on {}", listener.local_addr()?);
    let max_conns = if args.get_bool("once") { Some(1) } else { None };
    dspca::transport::serve_worker(
        listener,
        max_conns,
        std::time::Duration::from_secs(io_timeout_secs),
    )
}

/// Validate committed/produced benchmark snapshots against the report
/// schema using the in-tree JSON parser — the CI bench-snapshot job's
/// acceptance gate. A missing file, unparseable JSON, or a report
/// missing any schema field is a hard error.
fn cmd_bench_check(args: &Args) -> Result<()> {
    use dspca::util::json::Json;
    args.ensure_known_flags("bench-check", &["files", "out"])?;
    let files = args
        .get("files")
        .unwrap_or("BENCH_linalg.json,BENCH_topk.json,BENCH_serve.json,BENCH_obs.json,BENCH_wire.json");
    let mut checked = 0usize;
    for path in files.split(',').map(str::trim).filter(|s| !s.is_empty()) {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("bench-check: missing snapshot {path}"))?;
        let doc = Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("bench-check: {path}: invalid JSON: {e}"))?;
        let bench = doc
            .get("bench")
            .and_then(Json::as_str)
            .with_context(|| format!("bench-check: {path}: missing string field 'bench'"))?;
        anyhow::ensure!(
            matches!(doc.get("fast_mode"), Some(Json::Bool(_))),
            "bench-check: {path}: missing bool field 'fast_mode'"
        );
        anyhow::ensure!(
            doc.get("params").and_then(Json::as_obj).is_some(),
            "bench-check: {path}: missing object field 'params'"
        );
        let results = doc
            .get("results")
            .and_then(Json::as_arr)
            .with_context(|| format!("bench-check: {path}: missing array field 'results'"))?;
        anyhow::ensure!(!results.is_empty(), "bench-check: {path}: empty results array");
        for (i, r) in results.iter().enumerate() {
            let name = r
                .get("name")
                .and_then(Json::as_str)
                .with_context(|| format!("bench-check: {path}: result {i} missing 'name'"))?;
            for field in ["median_ns", "mean_ns", "p95_ns", "samples"] {
                anyhow::ensure!(
                    r.get(field).and_then(Json::as_f64).is_some(),
                    "bench-check: {path}: result '{name}' missing numeric '{field}'"
                );
            }
            anyhow::ensure!(
                matches!(r.get("bytes"), Some(Json::Num(_)) | Some(Json::Null)),
                "bench-check: {path}: result '{name}' has malformed 'bytes'"
            );
        }
        println!("bench-check: {path}: '{bench}' ok ({} results)", results.len());
        checked += 1;
    }
    anyhow::ensure!(checked > 0, "bench-check: no files given");
    Ok(())
}

fn cmd_e2e(args: &Args) -> Result<()> {
    use dspca::coordinator::{Algorithm, CentralizedErm, ShiftInvert, SignFixedAverage};
    use dspca::data::{CovModel, Distribution};
    args.ensure_known_flags("e2e", &["artifacts", "m", "n", "d", "seed", "out"])?;
    let artifacts = args
        .get("artifacts")
        .map(|s| s.to_string())
        .unwrap_or_else(|| dspca::runtime::default_artifact_dir().to_string_lossy().into_owned());
    let m = args.get_usize("m", 4)?;
    let n = args.get_usize("n", 400)?;
    let d = args.get_usize("d", 64)?;
    let seed = args.get_u64("seed", 0xe2e)?;
    let dist = CovModel::paper_fig1(d, seed ^ 1).gaussian();
    let spec = OracleSpec::Pjrt { artifact_dir: artifacts.clone() };
    println!("e2e: m={m} n={n} d={d} artifacts={artifacts}");
    let cluster = dspca::cluster::Cluster::generate_with(&dist, m, n, seed, spec)?;
    for alg in [&SignFixedAverage as &dyn Algorithm, &CentralizedErm, &ShiftInvert::default()] {
        let est = alg.run(&cluster.session())?;
        println!(
            "  {:<22} err={:.3e} rounds={} wall={:?}",
            alg.name(),
            est.error(dist.v1()),
            est.comm.rounds,
            est.wall
        );
    }
    Ok(())
}

/// Run the repo-invariant lint over `src/` and fail on any finding —
/// the CI `lint` job's gate. `--root` points at an alternate crate
/// root (directory containing `src/`); the default is this crate.
fn cmd_lint(args: &Args) -> Result<()> {
    use dspca::analysis::lint;
    args.ensure_known_flags("lint", &["root", "out"])?;
    let root = match args.get("root") {
        Some(dir) => std::path::PathBuf::from(dir),
        None => lint::default_root(),
    };
    let findings = lint::run(&root)
        .with_context(|| format!("lint: scanning {}", root.display()))?;
    if findings.is_empty() {
        println!("lint: {} clean (all repo invariants hold)", root.join("src").display());
        return Ok(());
    }
    for f in &findings {
        println!("{f}");
    }
    bail!("lint: {} finding(s) in {}", findings.len(), root.join("src").display());
}

fn cmd_selftest(args: &Args) -> Result<()> {
    use dspca::cluster::{Cluster, WireCodec, WirePrecision};
    use dspca::coordinator::{Algorithm, CentralizedErm, SignFixedAverage};
    use dspca::data::{CovModel, Distribution};
    args.ensure_known_flags("selftest", &["out"])?;
    let dist = CovModel::paper_fig1(24, 1).gaussian();
    let c = Cluster::generate(&dist, 4, 200, 2)?;
    let cen = CentralizedErm.run(&c.session())?;
    let fix = SignFixedAverage.run(&c.session())?;
    println!(
        "selftest[inproc]: centralized err={:.3e}, sign-fixed err={:.3e}",
        cen.error(dist.v1()),
        fix.error(dist.v1())
    );
    if cen.error(dist.v1()) > 0.5 {
        bail!("selftest failed: centralized ERM far from v1");
    }
    // the same queries over TCP loopback workers must produce the same
    // estimates and the same bills (the transport invariance contract)
    let workers = dspca::transport::LoopbackWorkers::spawn(4, 1)?;
    let t = Cluster::generate_on(&dist, 4, 200, 2, OracleSpec::Native, &workers.spec())?;
    let cen_t = CentralizedErm.run(&t.session())?;
    let fix_t = SignFixedAverage.run(&t.session())?;
    println!(
        "selftest[tcp]:    centralized err={:.3e}, sign-fixed err={:.3e}",
        cen_t.error(dist.v1()),
        fix_t.error(dist.v1())
    );
    if cen_t.w != cen.w || fix_t.w != fix.w {
        bail!("selftest failed: TCP backend estimates diverged from in-proc");
    }
    if cen_t.comm != cen.comm || fix_t.comm != fix.comm {
        bail!("selftest failed: TCP bill differs from in-proc bill");
    }
    drop(t);
    workers.join()?;

    // the split-phase overlap contract: two tenants with different wire
    // codecs keep rounds genuinely in flight at once — submit both,
    // then complete both — and each bills exactly its solo-run bill,
    // summing to the aggregate window, on both transports
    let v: Vec<f64> = (0..24).map(|i| ((i as f64) * 0.37).sin() + 0.05).collect();
    for backend in ["inproc", "tcp"] {
        let workers = (backend == "tcp")
            .then(|| dspca::transport::LoopbackWorkers::spawn(4, 1))
            .transpose()?;
        let spec = workers
            .as_ref()
            .map_or(dspca::transport::TransportSpec::InProc, |w| w.spec());
        let cluster = Cluster::generate_on(&dist, 4, 200, 2, OracleSpec::Native, &spec)?;
        // solo reference bills, one quiet round each
        let solo_lossless = {
            let s = cluster.session();
            s.dist_matvec(&v)?;
            s.close()
        };
        let solo_bf16 = {
            let s = cluster.session();
            s.set_codec(WireCodec::new(WirePrecision::Bf16));
            s.dist_matvec(&v)?;
            s.close()
        };
        // overlapped: both tenants' rounds on the wire before either
        // completes
        let agg0 = cluster.aggregate_stats();
        let lossless = cluster.session();
        let lossy = cluster.session();
        lossy.set_codec(WireCodec::new(WirePrecision::Bf16));
        let t1 = lossless.dist_matvec_submit(&v)?;
        let t2 = lossy.dist_matvec_submit(&v)?;
        let _ = t1.complete()?;
        let _ = t2.complete()?;
        let (b1, b2) = (lossless.close(), lossy.close());
        if b1 != solo_lossless || b2 != solo_bf16 {
            bail!(
                "selftest failed [{backend}]: overlapped bills diverged from solo \
                 (lossless {b1} vs {solo_lossless}; bf16 {b2} vs {solo_bf16})"
            );
        }
        let mut sum = b1.clone();
        sum.merge(&b2);
        if cluster.aggregate_stats().delta_since(&agg0) != sum {
            bail!("selftest failed [{backend}]: overlapped bills do not sum to the aggregate");
        }
        println!("selftest[{backend}]: overlapped mixed-codec tenants bill like solo runs");
        drop(cluster);
        if let Some(w) = workers {
            w.join()?;
        }
    }
    println!(
        "selftest OK (inproc + tcp loopback, identical estimates and bills, \
         split-phase overlap billing exact)"
    );
    Ok(())
}

/// Drive a small in-proc workload that touches every metric family —
/// a fused multi-tenant power sweep plus one pipelined block-power
/// solve — then print the process metrics snapshot (`--json` for the
/// machine-readable form). The quickest way to see what the flight
/// recorder captures; combine with `DSPCA_TRACE=` to get the matching
/// event timeline.
fn cmd_stats(args: &Args) -> Result<()> {
    args.ensure_known_flags("stats", &["json", "out"])?;
    let fcfg = serve_exp::FusionSweepConfig {
        d: 16,
        m: 3,
        n: 120,
        tenants: 2,
        iters: 3,
        window: std::time::Duration::from_millis(200),
        seed: 0x57a7,
        assert_speedup: None,
    };
    serve_exp::run_fusion(&fcfg).context("stats: fused workload")?;
    let dist = dspca::data::CovModel::paper_fig1(12, 3).gaussian();
    let c = dspca::cluster::Cluster::generate(&dist, 3, 80, 4)?;
    dspca::coordinator::DistributedOrthoIteration::new(2)
        .run_mat(&c.session())
        .context("stats: solver workload")?;
    let snap = dspca::obs::metrics::snapshot();
    if args.get_bool("json") {
        println!("{}", snap.to_json());
    } else {
        println!("{}", snap.to_text());
    }
    Ok(())
}

/// Parse a JSONL trace (produced via `DSPCA_TRACE=` / `--trace`),
/// print per-session round timelines, and cross-check that the traced
/// byte stream reproduces every closed session's bill exactly — the
/// trace-as-correctness-oracle gate CI runs after `serve --trace`.
/// `--chrome <out>` additionally writes a `chrome://tracing`-loadable
/// export (schema-validated before writing).
fn cmd_trace_report(args: &Args) -> Result<()> {
    use dspca::obs::report;
    args.ensure_known_flags("trace-report", &["file", "chrome", "out"])?;
    let path = args
        .get("file")
        .context("trace-report: --file <trace.jsonl> is required")?;
    let rep = report::report_from_file(path)?;
    print!("{}", rep.render());
    let checked = rep.crosscheck()?;
    println!("bill cross-check OK: {checked} closed session(s) reproduced from the trace");
    if let Some(out) = args.get("chrome") {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("trace-report: cannot re-read {path}"))?;
        // chrome_export schema-validates its own output before returning
        let chrome = report::chrome_export(text.lines())?;
        std::fs::write(out, format!("{chrome}\n"))
            .with_context(|| format!("trace-report: cannot write {out}"))?;
        println!("wrote chrome trace {out}");
    }
    Ok(())
}
