//! Artifact manifest: the contract between `python/compile/aot.py` and
//! the Rust runtime, parsed with the in-tree JSON parser.

use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

/// One AOT-compiled entry point at a fixed shard shape.
#[derive(Clone, Debug, PartialEq)]
pub struct ManifestEntry {
    pub name: String,
    pub n: usize,
    pub d: usize,
    pub file: String,
    pub inputs: Vec<Vec<usize>>,
    pub outputs: Vec<Vec<usize>>,
}

/// Parsed `artifacts/manifest.json`.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub dtype: String,
    pub entries: Vec<ManifestEntry>,
}

impl Manifest {
    pub fn load(path: impl AsRef<Path>) -> Result<Manifest> {
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text).map_err(|e| anyhow!("manifest JSON: {e}"))?;
        let version = j.get("version").and_then(Json::as_usize).unwrap_or(0);
        if version != 1 {
            bail!("unsupported manifest version {version}");
        }
        let dtype = j
            .get("dtype")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("manifest missing dtype"))?
            .to_string();
        if dtype != "f64" {
            bail!("runtime expects f64 artifacts, manifest says {dtype}");
        }
        let mut entries = Vec::new();
        for e in j
            .get("entries")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing entries"))?
        {
            let shape_list = |key: &str| -> Result<Vec<Vec<usize>>> {
                Ok(e.get(key)
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("entry missing {key}"))?
                    .iter()
                    .map(|s| {
                        s.as_arr()
                            .map(|dims| dims.iter().filter_map(Json::as_usize).collect())
                            .unwrap_or_default()
                    })
                    .collect())
            };
            entries.push(ManifestEntry {
                name: e
                    .get("name")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("entry missing name"))?
                    .to_string(),
                n: e.get("n").and_then(Json::as_usize).ok_or_else(|| anyhow!("entry missing n"))?,
                d: e.get("d").and_then(Json::as_usize).ok_or_else(|| anyhow!("entry missing d"))?,
                file: e
                    .get("file")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("entry missing file"))?
                    .to_string(),
                inputs: shape_list("inputs")?,
                outputs: shape_list("outputs")?,
            });
        }
        Ok(Manifest { dtype, entries })
    }

    /// Look up the artifact for an entry point at a shard shape.
    pub fn find(&self, name: &str, n: usize, d: usize) -> Option<&ManifestEntry> {
        self.entries.iter().find(|e| e.name == name && e.n == n && e.d == d)
    }

    /// All distinct shard shapes in the manifest.
    pub fn shapes(&self) -> Vec<(usize, usize)> {
        let mut shapes: Vec<(usize, usize)> = self.entries.iter().map(|e| (e.n, e.d)).collect();
        shapes.sort_unstable();
        shapes.dedup();
        shapes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "version": 1, "dtype": "f64",
        "entries": [
            {"name": "cov_matvec", "n": 400, "d": 64,
             "file": "cov_matvec_400x64.hlo.txt",
             "inputs": [[400, 64], [64]], "outputs": [[64]]},
            {"name": "gram", "n": 200, "d": 32,
             "file": "gram_200x32.hlo.txt",
             "inputs": [[200, 32]], "outputs": [[32, 32]]}
        ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.entries.len(), 2);
        let e = m.find("cov_matvec", 400, 64).unwrap();
        assert_eq!(e.file, "cov_matvec_400x64.hlo.txt");
        assert_eq!(e.inputs, vec![vec![400, 64], vec![64]]);
        assert!(m.find("cov_matvec", 401, 64).is_none());
    }

    #[test]
    fn shapes_deduped() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.shapes(), vec![(200, 32), (400, 64)]);
    }

    #[test]
    fn rejects_wrong_version_or_dtype() {
        assert!(Manifest::parse(r#"{"version": 2, "dtype": "f64", "entries": []}"#).is_err());
        assert!(Manifest::parse(r#"{"version": 1, "dtype": "f32", "entries": []}"#).is_err());
    }

    #[test]
    fn rejects_malformed_entry() {
        let bad = r#"{"version": 1, "dtype": "f64", "entries": [{"name": "x"}]}"#;
        assert!(Manifest::parse(bad).is_err());
    }
}
