//! The real PJRT engine and oracle (feature `pjrt`).
//!
//! Compiled only with `--features pjrt`, which requires the vendored
//! `xla` crate (xla_extension bindings) to be available to Cargo; the
//! default offline build uses [`super::stub`] instead so the crate
//! builds and tests with no native dependencies. See the module docs of
//! [`crate::runtime`] for the artifact flow.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::cluster::ComputeOracle;
use crate::data::Shard;
use crate::linalg::Matrix;

use super::Manifest;

/// Compiled-executable cache on one PJRT client.
pub struct Engine {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
    executables: HashMap<(String, usize, usize), xla::PjRtLoadedExecutable>,
}

impl Engine {
    /// Create a CPU PJRT engine over an artifact directory produced by
    /// `make artifacts`.
    pub fn new(artifact_dir: impl AsRef<Path>) -> Result<Engine> {
        let dir = artifact_dir.as_ref().to_path_buf();
        let manifest = Manifest::load(dir.join("manifest.json"))
            .with_context(|| format!("loading manifest from {}", dir.display()))?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT CPU client: {e}"))?;
        Ok(Engine { client, dir, manifest, executables: HashMap::new() })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) the executable for `(name, n, d)`.
    pub fn executable(
        &mut self,
        name: &str,
        n: usize,
        d: usize,
    ) -> Result<&xla::PjRtLoadedExecutable> {
        let key = (name.to_string(), n, d);
        if !self.executables.contains_key(&key) {
            let entry = self.manifest.find(name, n, d).ok_or_else(|| {
                anyhow!(
                    "no artifact for {name} at shape {n}x{d} \
                     (run `make artifacts` with DSPCA_AOT_SHAPES={n}x{d})"
                )
            })?;
            let path = self.dir.join(&entry.file);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow!("parsing {}: {e}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {name} {n}x{d}: {e}"))?;
            self.executables.insert(key.clone(), exe);
        }
        Ok(self.executables.get(&key).unwrap())
    }

    /// Upload a host array as a device buffer.
    pub fn upload(&self, data: &[f64], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer::<f64>(data, dims, None)
            .map_err(|e| anyhow!("uploading buffer: {e}"))
    }

    /// Execute an entry point on device buffers, returning the single
    /// (tupled) output as a host f64 vector.
    pub fn run(
        &mut self,
        name: &str,
        n: usize,
        d: usize,
        args: &[&xla::PjRtBuffer],
    ) -> Result<Vec<f64>> {
        let exe = self.executable(name, n, d)?;
        let outs = exe.execute_b(args).map_err(|e| anyhow!("executing {name}: {e}"))?;
        let lit = outs
            .first()
            .and_then(|r| r.first())
            .ok_or_else(|| anyhow!("no output from {name}"))?
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching {name} output: {e}"))?;
        // aot.py lowers with return_tuple=True -> 1-tuple
        let out = lit.to_tuple1().map_err(|e| anyhow!("untupling {name} output: {e}"))?;
        out.to_vec::<f64>().map_err(|e| anyhow!("reading {name} output: {e}"))
    }
}

/// Worker compute oracle backed by the PJRT engine.
///
/// Holds the shard's device buffer after first use, so the steady-state
/// request cost is: upload `v` (d doubles) + execute + download result.
pub struct PjrtOracle {
    engine: Engine,
    shard_buf: Option<(usize, usize, xla::PjRtBuffer)>,
}

impl PjrtOracle {
    pub fn new(artifact_dir: impl AsRef<Path>) -> Result<PjrtOracle> {
        Ok(PjrtOracle { engine: Engine::new(artifact_dir)?, shard_buf: None })
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    fn ensure_shard_buffer(&mut self, shard: &Shard) -> Result<()> {
        let (n, d) = (shard.n(), shard.d());
        let fresh = match &self.shard_buf {
            Some((bn, bd, _)) => *bn != n || *bd != d,
            None => true,
        };
        if fresh {
            // the AOT kernels are dense-only; a CSR shard surfaces as a
            // per-request error (mirroring oracle-init failures) rather
            // than a panic in the worker thread
            let dense = shard.try_dense().ok_or_else(|| {
                anyhow!("pjrt oracle: sparse (CSR) shards are not supported by the AOT kernels")
            })?;
            let buf = self.engine.upload(dense.data(), &[n, d])?;
            self.shard_buf = Some((n, d, buf));
        }
        Ok(())
    }

    fn run_with_shard(
        &mut self,
        name: &str,
        shard: &Shard,
        extra: &[xla::PjRtBuffer],
    ) -> Result<Vec<f64>> {
        let (n, d) = (shard.n(), shard.d());
        self.ensure_shard_buffer(shard)?;
        let shard_buf = &self.shard_buf.as_ref().unwrap().2;
        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(1 + extra.len());
        args.push(shard_buf);
        args.extend(extra.iter());
        self.engine.run(name, n, d, &args)
    }
}

impl ComputeOracle for PjrtOracle {
    fn cov_matvec(&mut self, shard: &Shard, v: &[f64]) -> Result<Vec<f64>> {
        if v.len() != shard.d() {
            bail!("cov_matvec: dim mismatch");
        }
        let vbuf = self.engine.upload(v, &[v.len()])?;
        self.run_with_shard("cov_matvec", shard, &[vbuf])
    }

    fn local_top_eigvec(&mut self, shard: &Shard) -> Result<Vec<f64>> {
        // deterministic start vector (any non-orthogonal start converges)
        let d = shard.d();
        let v0 = vec![1.0 / (d as f64).sqrt(); d];
        let vbuf = self.engine.upload(&v0, &[d])?;
        self.run_with_shard("local_top_eigvec", shard, &[vbuf])
    }

    fn gram(&mut self, shard: &Shard) -> Result<Matrix> {
        let d = shard.d();
        let flat = self.run_with_shard("gram", shard, &[])?;
        if flat.len() != d * d {
            bail!("gram: expected {}x{} output, got {} elements", d, d, flat.len());
        }
        Ok(Matrix::from_vec(d, d, flat))
    }

    fn oja_pass(
        &mut self,
        shard: &Shard,
        w: &[f64],
        eta0: f64,
        t0: f64,
        t_start: u64,
    ) -> Result<Vec<f64>> {
        let wbuf = self.engine.upload(w, &[w.len()])?;
        let e = self.engine.upload(&[eta0], &[])?;
        let t = self.engine.upload(&[t0], &[])?;
        let ts = self.engine.upload(&[t_start as f64], &[])?;
        self.run_with_shard("oja_pass", shard, &[wbuf, e, t, ts])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::default_artifact_dir;

    fn artifacts_dir() -> Option<PathBuf> {
        let dir = default_artifact_dir();
        if dir.join("manifest.json").exists() {
            Some(dir)
        } else {
            eprintln!("skipping PJRT test: run `make artifacts` first");
            None
        }
    }

    fn test_shard(n: usize, d: usize, seed: u64) -> Shard {
        let mut rng = crate::rng::Pcg64::new(seed);
        Shard::new(n, d, (0..n * d).map(|_| rng.next_gaussian()).collect())
    }

    #[test]
    fn engine_loads_manifest() {
        let Some(dir) = artifacts_dir() else { return };
        let engine = Engine::new(&dir).unwrap();
        assert!(!engine.manifest().entries.is_empty());
        assert!(!engine.platform().is_empty());
    }

    #[test]
    fn pjrt_cov_matvec_matches_native() {
        let Some(dir) = artifacts_dir() else { return };
        let shard = test_shard(400, 64, 1);
        let mut oracle = PjrtOracle::new(&dir).unwrap();
        let mut rng = crate::rng::Pcg64::new(2);
        let v = rng.gaussian_vec(64);
        let got = oracle.cov_matvec(&shard, &v).unwrap();
        let want = shard.cov_matvec(&v);
        for i in 0..64 {
            assert!(
                (got[i] - want[i]).abs() < 1e-10 * (1.0 + want[i].abs()),
                "mismatch at {i}: {} vs {}",
                got[i],
                want[i]
            );
        }
    }

    #[test]
    fn pjrt_gram_matches_native() {
        let Some(dir) = artifacts_dir() else { return };
        let shard = test_shard(200, 32, 3);
        let mut oracle = PjrtOracle::new(&dir).unwrap();
        let got = oracle.gram(&shard).unwrap();
        let want = shard.empirical_covariance();
        assert!(got.sub(want).max_abs() < 1e-10);
    }

    #[test]
    fn pjrt_local_eigvec_matches_native() {
        let Some(dir) = artifacts_dir() else { return };
        // a shard with a real eigengap (paper model, delta = 0.2): the
        // artifact's fixed power-iteration count needs gap^iters to
        // underflow the tolerance, which a near-degenerate Wishart shard
        // (iid gaussian) does not give at any reasonable iteration count.
        let dist = crate::data::CovModel::paper_fig1(64, 5).gaussian();
        let mut rng = crate::rng::Pcg64::new(55);
        let shard = crate::data::Distribution::sample_shard(&dist, &mut rng, 400);
        let mut oracle = PjrtOracle::new(&dir).unwrap();
        let got = oracle.local_top_eigvec(&shard).unwrap();
        let want = shard.local_top_eigvec();
        let align = crate::linalg::vec_ops::alignment_error(&got, &want);
        assert!(align < 1e-9, "alignment error {align}");
    }

    #[test]
    fn pjrt_oja_pass_matches_native() {
        let Some(dir) = artifacts_dir() else { return };
        let shard = test_shard(200, 32, 7);
        let mut oracle = PjrtOracle::new(&dir).unwrap();
        let mut native = crate::cluster::NativeOracle::default();
        let mut w0 = vec![0.0; 32];
        w0[0] = 1.0;
        let got = oracle.oja_pass(&shard, &w0, 0.5, 10.0, 100).unwrap();
        let want = native.oja_pass(&shard, &w0, 0.5, 10.0, 100).unwrap();
        for i in 0..32 {
            assert!((got[i] - want[i]).abs() < 1e-9, "mismatch at {i}");
        }
    }

    #[test]
    fn missing_shape_reports_helpful_error() {
        let Some(dir) = artifacts_dir() else { return };
        let shard = test_shard(3, 3, 9);
        let mut oracle = PjrtOracle::new(&dir).unwrap();
        let err = oracle.cov_matvec(&shard, &[1.0, 0.0, 0.0]).unwrap_err();
        assert!(err.to_string().contains("DSPCA_AOT_SHAPES"), "err: {err}");
    }

    #[test]
    fn cluster_end_to_end_with_pjrt_oracle() {
        let Some(dir) = artifacts_dir() else { return };
        use crate::cluster::{Cluster, OracleSpec};
        use crate::coordinator::{Algorithm, CentralizedErm, SignFixedAverage};
        use crate::data::CovModel;
        let dist = CovModel::paper_fig1(32, 3).gaussian();
        let spec = OracleSpec::Pjrt { artifact_dir: dir.to_string_lossy().into_owned() };
        let c = Cluster::generate_with(&dist, 3, 200, 42, spec).unwrap();
        let est = SignFixedAverage.run(&c.session()).unwrap();
        let cen = CentralizedErm.run(&c.session()).unwrap();
        // both estimators run entirely through PJRT-backed workers
        let e = crate::linalg::vec_ops::alignment_error(&est.w, &cen.w);
        assert!(e < 0.2, "pjrt-backed estimators disagree wildly: {e}");
    }
}
