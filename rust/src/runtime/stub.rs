//! Stub PJRT oracle for builds **without** the `pjrt` feature.
//!
//! The offline image has no `xla` crate (xla_extension bindings), so the
//! default build compiles this stub instead of [`super::pjrt`]: the same
//! public surface ([`PjrtOracle`]), but construction fails with an
//! actionable error. Everything that only *inspects* artifacts — the
//! [`super::Manifest`] parser, [`super::default_artifact_dir`] — stays
//! available unconditionally, so artifact-gated tests and benches skip
//! gracefully rather than failing to compile.

use std::path::Path;

use anyhow::{bail, Result};

use crate::cluster::ComputeOracle;
use crate::data::Shard;
use crate::linalg::Matrix;

/// Placeholder for the PJRT-backed worker oracle. Construction always
/// fails in this build; see the module docs.
pub struct PjrtOracle {
    _private: (),
}

impl PjrtOracle {
    pub fn new(artifact_dir: impl AsRef<Path>) -> Result<PjrtOracle> {
        bail!(
            "PJRT runtime not compiled in (artifact dir {}): \
             rebuild with `cargo build --features pjrt` and a vendored `xla` crate",
            artifact_dir.as_ref().display()
        )
    }
}

impl ComputeOracle for PjrtOracle {
    fn cov_matvec(&mut self, _shard: &Shard, _v: &[f64]) -> Result<Vec<f64>> {
        bail!("PJRT runtime not compiled in (`pjrt` feature disabled)")
    }

    fn local_top_eigvec(&mut self, _shard: &Shard) -> Result<Vec<f64>> {
        bail!("PJRT runtime not compiled in (`pjrt` feature disabled)")
    }

    fn gram(&mut self, _shard: &Shard) -> Result<Matrix> {
        bail!("PJRT runtime not compiled in (`pjrt` feature disabled)")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_construction_fails_with_actionable_error() {
        let err = PjrtOracle::new("artifacts").unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("pjrt"), "error should name the feature: {msg}");
        assert!(msg.contains("artifacts"), "error should name the directory: {msg}");
    }

    #[test]
    fn pjrt_oracle_spec_surfaces_stub_error_per_request() {
        // a cluster built with a PJRT spec must not crash: the worker
        // surfaces the construction failure on the first request
        use crate::cluster::{Cluster, OracleSpec};
        use crate::data::CovModel;
        let dist = CovModel::paper_fig1(4, 1).gaussian();
        let spec = OracleSpec::Pjrt { artifact_dir: "does-not-exist".into() };
        let c = Cluster::generate_with(&dist, 2, 10, 3, spec).unwrap();
        let err = c.session().dist_matvec(&[1.0, 0.0, 0.0, 0.0]).unwrap_err();
        assert!(err.to_string().contains("failed"), "unexpected error: {err}");
    }
}
