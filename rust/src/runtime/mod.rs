//! PJRT runtime: loads the AOT-compiled JAX/Pallas artifacts and runs
//! them from the worker hot path.
//!
//! Flow: `python/compile/aot.py` lowers the L2 entry points to HLO *text*
//! (`artifacts/<entry>_<n>x<d>.hlo.txt` + `manifest.json`); this module
//! parses the manifest ([`Manifest`]), compiles each needed executable
//! once per (entry, shape) on a `PjRtClient`, and exposes the
//! worker-facing [`PjrtOracle`] implementing
//! [`crate::cluster::ComputeOracle`].
//!
//! ## Feature gate
//!
//! The PJRT client lives in the `xla` crate (xla_extension bindings),
//! which the offline build image does not carry. The real engine
//! therefore sits behind the **`pjrt` cargo feature** (`pjrt.rs`); the
//! default build compiles a stub (`stub.rs`) whose `PjrtOracle::new`
//! fails with an actionable error, while the manifest parser and
//! [`default_artifact_dir`] remain available unconditionally so
//! artifact-gated tests and benches skip gracefully. Enabling `pjrt`
//! requires a vendored `xla` crate visible to Cargo.
//!
//! Design notes (real engine):
//! - HLO **text** (not serialized protos) is the interchange format —
//!   jax >= 0.5 emits 64-bit instruction ids that xla_extension 0.5.1
//!   rejects; the text parser reassigns ids.
//! - The PJRT client is **not** `Send`, so each worker thread constructs
//!   its own oracle from [`crate::cluster::OracleSpec::Pjrt`].
//! - The shard is uploaded to the device **once** per oracle and reused
//!   across every request; only the `d`-vector argument moves per call.
//!   All artifacts are f64 (`jax_enable_x64`), bit-comparable with the
//!   native oracle.
//! - Block requests ([`crate::cluster::Request::CovMatMat`]) are served
//!   through the [`crate::cluster::ComputeOracle::cov_matmat`] default
//!   (a worker-local loop over the `cov_matvec` artifact), so the block
//!   protocol's one-message-per-worker round shape holds on the PJRT
//!   path too; a fused matmat artifact is an open roadmap item.

mod manifest;

pub use manifest::{Manifest, ManifestEntry};

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::{Engine, PjrtOracle};

#[cfg(not(feature = "pjrt"))]
mod stub;
#[cfg(not(feature = "pjrt"))]
pub use stub::PjrtOracle;

use std::path::PathBuf;

/// Default artifact directory: `$DSPCA_ARTIFACTS` or `<crate>/artifacts`.
pub fn default_artifact_dir() -> PathBuf {
    std::env::var("DSPCA_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_artifact_dir_is_absolute_or_env() {
        let dir = default_artifact_dir();
        assert!(!dir.as_os_str().is_empty());
    }
}
