//! PCG XSL-RR 128/64: 128-bit LCG state with a 64-bit xor-shift /
//! random-rotation output function (O'Neill, "PCG: A Family of Simple
//! Fast Space-Efficient Statistically Good Algorithms for Random Number
//! Generation", 2014). Rust 1.95's native `u128` makes this a direct
//! transcription.

/// PCG64 generator. `Clone` is deliberate: tests snapshot generator state.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
    cached_gaussian: Option<f64>,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Seed a generator. Two different seeds give independent-looking
    /// streams; the sequence for a given seed is stable forever (recorded
    /// in EXPERIMENTS.md next to each result).
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Seed with an explicit stream id (odd-ified internally).
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        // splitmix the seed into 128 bits of state material
        let mut sm = SplitMix64 { state: seed };
        let s0 = sm.next() as u128;
        let s1 = sm.next() as u128;
        let mut rng = Pcg64 {
            state: 0,
            inc: (((stream as u128) << 1) | 1) ^ (s1 << 64),
            cached_gaussian: None,
        };
        rng.inc |= 1;
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(s0 | (s1 << 64));
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng
    }

    /// Derive an independent child generator (used to give every worker /
    /// run its own stream from one experiment seed).
    pub fn fork(&mut self, tag: u64) -> Pcg64 {
        let a = self.next_u64();
        Pcg64::with_stream(a ^ tag.wrapping_mul(0x9e37_79b9_7f4a_7c15), tag.wrapping_add(1))
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        let rot = (self.state >> 122) as u32;
        xored.rotate_right(rot)
    }

    /// Uniform `f64` in `[0, 1)` with 53 random bits.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub(crate) fn take_cached_gaussian(&mut self) -> Option<f64> {
        self.cached_gaussian.take()
    }

    pub(crate) fn cache_gaussian(&mut self, z: f64) {
        self.cached_gaussian = Some(z);
    }
}

struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    fn next(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9e37_79b9_7f4a_7c15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        z ^ (z >> 31)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = Pcg64::new(3);
        for _ in 0..10_000 {
            let v = rng.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn uniformity_coarse_chi2() {
        let mut rng = Pcg64::new(4);
        let mut bins = [0u32; 16];
        let n = 160_000;
        for _ in 0..n {
            bins[(rng.next_f64() * 16.0) as usize] += 1;
        }
        let expect = n as f64 / 16.0;
        let chi2: f64 = bins.iter().map(|&c| (c as f64 - expect).powi(2) / expect).sum();
        // 15 dof; 99.9th percentile ~ 37.7
        assert!(chi2 < 45.0, "chi2={chi2}");
    }

    #[test]
    fn fork_streams_diverge() {
        let mut root = Pcg64::new(9);
        let mut c1 = root.fork(1);
        let mut c2 = root.fork(2);
        let same = (0..64).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn monobit_balance() {
        let mut rng = Pcg64::new(11);
        let mut ones = 0u64;
        let n = 10_000;
        for _ in 0..n {
            ones += rng.next_u64().count_ones() as u64;
        }
        let frac = ones as f64 / (n as f64 * 64.0);
        assert!((frac - 0.5).abs() < 0.005, "frac={frac}");
    }
}
