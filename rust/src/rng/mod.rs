//! Deterministic random-number substrate.
//!
//! The offline image carries no `rand` crate, so the generator and the
//! samplers the paper's experiments need are implemented here:
//!
//! - [`Pcg64`] — PCG XSL-RR 128/64 generator (O'Neill 2014): 64-bit
//!   outputs, splittable via `fork`, reproducible across runs (every
//!   experiment records its seed).
//! - gaussian sampling (Box–Muller with caching),
//! - Rademacher and the paper's asymmetric `xi` variable (Lemma 9).

mod pcg;

pub use pcg::Pcg64;

impl Pcg64 {
    /// Standard normal via the Marsaglia polar method (pair-cached).
    /// ~1.6x faster than Box–Muller on this box: no sin/cos, one ln+sqrt
    /// per accepted pair, 21.5% rejection (EXPERIMENTS.md §Perf).
    pub fn next_gaussian(&mut self) -> f64 {
        if let Some(z) = self.take_cached_gaussian() {
            return z;
        }
        loop {
            let u = 2.0 * self.next_f64() - 1.0;
            let v = 2.0 * self.next_f64() - 1.0;
            let s = u * u + v * v;
            if s >= 1.0 || s == 0.0 {
                continue;
            }
            let mul = (-2.0 * s.ln() / s).sqrt();
            self.cache_gaussian(v * mul);
            return u * mul;
        }
    }

    /// Vector of i.i.d. standard normals.
    pub fn gaussian_vec(&mut self, d: usize) -> Vec<f64> {
        (0..d).map(|_| self.next_gaussian()).collect()
    }

    /// Uniform in `[-1, 1)`.
    pub fn next_sym_uniform(&mut self) -> f64 {
        2.0 * self.next_f64() - 1.0
    }

    /// Rademacher: ±1 with probability 1/2 each.
    pub fn next_rademacher(&mut self) -> f64 {
        if self.next_u64() & 1 == 0 {
            1.0
        } else {
            -1.0
        }
    }

    /// The asymmetric variable of the paper's Lemma 9:
    /// `xi = sqrt(2)` w.p. 1/3, `-1/sqrt(2)` w.p. 2/3.
    /// (`E[xi] = 0`, `E[xi^2] = 1`, `E[xi^3] = 1/sqrt(2)`.)
    pub fn next_asymmetric_xi(&mut self) -> f64 {
        if self.next_f64() < 1.0 / 3.0 {
            std::f64::consts::SQRT_2
        } else {
            -1.0 / std::f64::consts::SQRT_2
        }
    }

    /// Uniform integer in `[0, n)`. Uses rejection to kill modulo bias.
    pub fn next_below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gaussian_moments() {
        let mut rng = Pcg64::new(1234);
        let n = 200_000;
        let (mut sum, mut sumsq, mut sum3) = (0.0, 0.0, 0.0);
        for _ in 0..n {
            let z = rng.next_gaussian();
            sum += z;
            sumsq += z * z;
            sum3 += z * z * z;
        }
        let mean = sum / n as f64;
        let var = sumsq / n as f64 - mean * mean;
        let skew = sum3 / n as f64;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
        assert!(skew.abs() < 0.05, "skew={skew}");
    }

    #[test]
    fn asymmetric_xi_moments_match_lemma9() {
        let mut rng = Pcg64::new(99);
        let n = 400_000;
        let (mut m1, mut m2, mut m3) = (0.0, 0.0, 0.0);
        for _ in 0..n {
            let x = rng.next_asymmetric_xi();
            m1 += x;
            m2 += x * x;
            m3 += x * x * x;
        }
        let inv = 1.0 / n as f64;
        assert!((m1 * inv).abs() < 0.01);
        assert!((m2 * inv - 1.0).abs() < 0.01);
        assert!((m3 * inv - 1.0 / std::f64::consts::SQRT_2).abs() < 0.02);
    }

    #[test]
    fn rademacher_balanced() {
        let mut rng = Pcg64::new(5);
        let n = 100_000;
        let sum: f64 = (0..n).map(|_| rng.next_rademacher()).sum();
        assert!(sum.abs() / n as f64 <= 0.02);
    }

    #[test]
    fn next_below_in_range_and_covers() {
        let mut rng = Pcg64::new(6);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = rng.next_below(7);
            assert!(v < 7);
            seen[v as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn sym_uniform_range_and_mean() {
        let mut rng = Pcg64::new(7);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let v = rng.next_sym_uniform();
            assert!((-1.0..1.0).contains(&v));
            sum += v;
        }
        assert!((sum / n as f64).abs() < 0.01);
    }
}
