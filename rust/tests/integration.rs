//! Cross-module integration tests: full pipelines over the public API,
//! plus property-based invariants on the coordinator, the cluster's
//! block protocol, and the multi-tenant session layer (propcheck).

use dspca::cluster::{Cluster, CommStats, Session, WireCodec, WirePrecision};
use dspca::coordinator::subspace::subspace_error;
use dspca::coordinator::{
    Algorithm, BlockLanczos, CentralizedErm, DistributedLanczos, DistributedOrthoIteration,
    DistributedPower, HotPotatoOja, NaiveAverage, ProjectionAverage, QuantizedPower, ShiftInvert,
    SignFixedAverage, SniConfig,
};
use dspca::data::{CovModel, Distribution, Thm3Dist};
use dspca::linalg::qr::{orthonormality_defect, qr_thin};
use dspca::linalg::vec_ops::{alignment_error, norm};
use dspca::linalg::Matrix;
use dspca::propcheck::{run as propcheck, Config};

fn fig1(m: usize, n: usize, d: usize, seed: u64) -> (Cluster, impl Distribution) {
    let dist = CovModel::paper_fig1(d, seed ^ 0x77).gaussian();
    let c = Cluster::generate(&dist, m, n, seed).unwrap();
    (c, dist)
}

#[test]
fn all_algorithms_produce_unit_estimates() {
    let (c, dist) = fig1(4, 120, 16, 1);
    let algs: Vec<Box<dyn Algorithm>> = vec![
        Box::new(CentralizedErm),
        Box::new(NaiveAverage),
        Box::new(SignFixedAverage),
        Box::new(ProjectionAverage),
        Box::new(DistributedPower::default()),
        Box::new(DistributedLanczos::default()),
        Box::new(HotPotatoOja::default()),
        Box::new(ShiftInvert::default()),
    ];
    for alg in &algs {
        let est = alg.run(&c.session()).unwrap();
        assert!((norm(&est.w) - 1.0).abs() < 1e-9, "{} not unit norm", alg.name());
        let err = est.error(dist.v1());
        assert!((0.0..=1.0).contains(&err), "{} error {err} out of range", alg.name());
    }
}

#[test]
fn exact_methods_agree_on_the_pooled_eigenvector() {
    let (c, _) = fig1(5, 300, 24, 3);
    let cen = CentralizedErm.run(&c.session()).unwrap();
    for alg in [
        &DistributedPower::default() as &dyn Algorithm,
        &DistributedLanczos::default(),
        &ShiftInvert::default(),
    ] {
        let est = alg.run(&c.session()).unwrap();
        let e = alignment_error(&est.w, &cen.w);
        assert!(e < 1e-6, "{} disagrees with centralized ERM: {e:.3e}", alg.name());
    }
}

#[test]
fn determinism_full_pipeline() {
    // same seed -> identical estimates end-to-end (data gen, worker sign
    // coins, algorithms)
    let run_once = || {
        let (c, dist) = fig1(4, 80, 8, 99);
        let a = SignFixedAverage.run(&c.session()).unwrap();
        let b = ShiftInvert::default().run(&c.session()).unwrap();
        let err = a.error(dist.v1());
        (a.w, b.w, err)
    };
    let (w1, s1, e1) = run_once();
    let (w2, s2, e2) = run_once();
    assert_eq!(w1, w2);
    assert_eq!(s1, s2);
    assert_eq!(e1, e2);
}

#[test]
fn failure_injection_degrades_gracefully() {
    let (c, dist) = fig1(6, 100, 8, 7);
    c.kill_worker(3).unwrap();
    c.kill_worker(5).unwrap();
    assert_eq!(c.live(), 4);
    // algorithms still run over the surviving machines
    let est = SignFixedAverage.run(&c.session()).unwrap();
    assert!(est.error(dist.v1()) < 0.8);
    assert_eq!(est.comm.vectors_gathered, 4);
    let sni = ShiftInvert::default().run(&c.session()).unwrap();
    assert!(alignment_error(&sni.w, &CentralizedErm.run(&c.session()).unwrap().w) < 1e-5);
}

#[test]
fn comm_accounting_is_additive_across_runs() {
    let (c, _) = fig1(3, 60, 6, 11);
    let a = DistributedPower { max_iters: 5, tol: 0.0, seed: 1, warm_start: false }
        .run(&c.session())
        .unwrap();
    let b = DistributedPower { max_iters: 9, tol: 0.0, seed: 1, warm_start: false }
        .run(&c.session())
        .unwrap();
    assert_eq!(a.comm.rounds, 5);
    assert_eq!(b.comm.rounds, 9);
    // each estimate carries only its own session's bill
    assert_eq!(a.comm.matvec_products + b.comm.matvec_products, 14);
}

// ---------------------------------------------------------------------
// Multi-tenant session layer (the ISSUE 3 tentpole): concurrent bills
// are solo bills, and they sum to the cluster aggregate.
// ---------------------------------------------------------------------

/// THE acceptance test: two algorithm jobs — one lossless, one through a
/// lossy bf16 wire codec — running **concurrently** on one shared
/// cluster must produce per-session bills that are each identical to
/// their solo-run bills (same rounds/messages/bytes) and that sum to
/// the cluster's aggregate over the window.
#[test]
fn concurrent_lossless_and_lossy_tenants_bill_exactly_like_solo_runs() {
    let (c, _) = fig1(4, 150, 12, 21);
    let power = DistributedPower::default();
    let quant = QuantizedPower::new(WirePrecision::Bf16);
    // solo reference runs on an otherwise idle cluster
    let solo_power = power.run(&c.session()).unwrap();
    let solo_quant = quant.run(&c.session()).unwrap();
    assert!(solo_power.comm.bytes > 0 && solo_quant.comm.bytes > 0);
    // concurrent runs, one session per tenant thread
    let agg0 = c.aggregate_stats();
    let (conc_power, conc_quant) = std::thread::scope(|s| {
        let h1 = s.spawn(|| power.run(&c.session()).unwrap());
        let h2 = s.spawn(|| quant.run(&c.session()).unwrap());
        (h1.join().unwrap(), h2.join().unwrap())
    });
    // same estimates (interleaving cannot change the numerics)…
    assert_eq!(conc_power.w, solo_power.w);
    assert_eq!(conc_quant.w, solo_quant.w);
    // …and bill-for-bill identical accounting
    assert_eq!(conc_power.comm, solo_power.comm, "lossless tenant's bill changed under load");
    assert_eq!(conc_quant.comm, solo_quant.comm, "lossy tenant's bill changed under load");
    // the lossy tenant did not degrade or inflate the lossless one:
    // bf16 rounds cost 1/4 the bytes of f64 rounds of the same shape
    assert_eq!(
        solo_quant.comm.bytes * 4,
        solo_quant.comm.rounds * (8 * 12 * 5),
        "bf16 tenant ships 2-byte frames"
    );
    // sum of the two bills == the aggregate window
    let mut sum = conc_power.comm.clone();
    sum.merge(&conc_quant.comm);
    assert_eq!(sum, c.aggregate_stats().delta_since(&agg0));
}

/// Same acceptance property through the `serve` scheduler path.
#[test]
fn serve_scheduler_preserves_solo_bills_for_mixed_codec_jobs() {
    use dspca::serve::{serve, Job};
    let (c, _) = fig1(3, 100, 10, 23);
    let solo_power = DistributedPower::default().run(&c.session()).unwrap();
    let solo_quant = QuantizedPower::new(WirePrecision::Bf16).run(&c.session()).unwrap();
    let agg0 = c.aggregate_stats();
    let report = serve(
        &c,
        vec![
            Job::new("lossless-power", Box::new(DistributedPower::default())),
            Job::new("bf16-power", Box::new(QuantizedPower::new(WirePrecision::Bf16))),
        ],
        2,
    )
    .unwrap();
    assert_eq!(report.jobs[0].comm, solo_power.comm);
    assert_eq!(report.jobs[1].comm, solo_quant.comm);
    assert!(report.accounting_exact, "exclusive batch: Σ bills == aggregate");
    assert_eq!(report.aggregate, c.aggregate_stats().delta_since(&agg0));
}

/// Propcheck (ISSUE 3 satellite, property a): for every collective ×
/// every codec, the sum of per-session `CommStats` across concurrent
/// tenants equals the cluster's aggregate bill over the window.
#[test]
fn prop_concurrent_session_bills_sum_to_cluster_aggregate() {
    propcheck(Config::default().cases(6), "session bill additivity", |g| {
        let m = g.usize_in(1, 4);
        let n = g.usize_in(5, 25);
        let d = g.usize_in(2, 8);
        let k = g.usize_in(1, d);
        let seed = g.rng().next_u64();
        let dist = CovModel::paper_fig1(d, 6).gaussian();
        let c = Cluster::generate(&dist, m, n, seed).unwrap();
        if m > 1 && g.bool() {
            c.kill_worker(g.usize_in(1, m - 1)).unwrap();
        }
        // pre-generate per-tenant payloads (Gen is not Sync)
        let payloads: Vec<Vec<f64>> = (0..3).map(|_| g.gaussian_vec(d)).collect();
        let agg0 = c.aggregate_stats();
        let codecs = [WirePrecision::F64, WirePrecision::F32, WirePrecision::Bf16];
        // three tenants, one codec each, every collective — concurrently
        let bills: Vec<CommStats> = std::thread::scope(|s| {
            let handles: Vec<_> = codecs
                .iter()
                .zip(&payloads)
                .map(|(&prec, payload)| {
                    let c = &c;
                    s.spawn(move || {
                        let sess = c.session();
                        sess.set_codec(WireCodec::new(prec));
                        sess.dist_matvec(payload).unwrap();
                        let mut v = Matrix::zeros(d, k);
                        for col in 0..k {
                            v.set_col(col, payload);
                        }
                        sess.dist_matmat(&v).unwrap();
                        sess.local_top_eigvecs(false).unwrap();
                        sess.local_top_k(k).unwrap();
                        sess.gram_average().unwrap();
                        sess.oja_chain(payload, 0.5, 10.0).unwrap();
                        sess.stats()
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        let mut sum = CommStats::default();
        for b in &bills {
            sum.merge(b);
        }
        assert_eq!(sum, c.aggregate_stats().delta_since(&agg0));
        // and each tenant's per-codec byte bill is its solo-table bill
        let live = c.live() as u64;
        for (prec, bill) in codecs.iter().zip(&bills) {
            let b = |words: usize| (words * prec.bytes_per_entry()) as u64;
            let want = b(d) * (live + 1)      // dist_matvec
                + b(d * k) * (live + 1)       // dist_matmat
                + b(d) * live                 // local_top_eigvecs
                + b(d * k) * live             // local_top_k
                + b(d * d) * live             // gram_average
                + 2 * b(d) * live; // oja_chain
            assert_eq!(bill.bytes, want, "{prec:?} tenant bytes");
        }
    });
}

/// Propcheck (ISSUE 3 satellite, property b): a single-session run
/// under the default codec reproduces the pre-refactor accounting table
/// verbatim — the `8·d·…` rows asserted field by field.
#[test]
fn prop_single_session_reproduces_legacy_accounting_verbatim() {
    propcheck(Config::default().cases(8), "legacy accounting table", |g| {
        let m = g.usize_in(1, 5);
        let n = g.usize_in(5, 25);
        let d = g.usize_in(2, 10);
        let k = g.usize_in(1, d);
        let seed = g.rng().next_u64();
        let dist = CovModel::paper_fig1(d, 7).gaussian();
        let c = Cluster::generate(&dist, m, n, seed).unwrap();
        if m > 1 && g.bool() {
            c.kill_worker(g.usize_in(1, m - 1)).unwrap();
        }
        let live = c.live() as u64;
        let du = d as u64;
        let ku = k as u64;

        let s = c.session();
        s.dist_matvec(&g.gaussian_vec(d)).unwrap();
        let st = s.stats();
        assert_eq!(
            (st.rounds, st.matvec_products, st.vectors_broadcast, st.vectors_gathered),
            (1, 1, 1, live)
        );
        assert_eq!((st.requests_sent, st.responses_received), (live, live));
        assert_eq!(st.bytes, 8 * du * (live + 1));

        let s = c.session();
        s.dist_matmat(&random_block(g, d, k)).unwrap();
        let st = s.stats();
        assert_eq!(
            (st.rounds, st.matvec_products, st.vectors_broadcast, st.vectors_gathered),
            (1, ku, ku, live * ku)
        );
        assert_eq!(st.bytes, 8 * du * ku * (live + 1));

        let s = c.session();
        s.local_top_eigvecs(false).unwrap();
        let st = s.stats();
        assert_eq!((st.rounds, st.vectors_gathered, st.bytes), (1, live, 8 * du * live));

        let s = c.session();
        s.local_top_k(k).unwrap();
        let st = s.stats();
        assert_eq!((st.rounds, st.vectors_gathered, st.bytes), (1, live * ku, 8 * du * ku * live));

        let s = c.session();
        s.gram_average().unwrap();
        let st = s.stats();
        assert_eq!((st.rounds, st.vectors_gathered, st.bytes), (1, live * du, 8 * du * du * live));

        let s = c.session();
        let mut w0 = vec![0.0; d];
        w0[0] = 1.0;
        s.oja_chain(&w0, 0.5, 10.0).unwrap();
        let st = s.stats();
        assert_eq!((st.rounds, st.vectors_broadcast, st.vectors_gathered), (live, live, live));
        assert_eq!(st.bytes, 2 * 8 * du * live);
    });
}

#[test]
fn prop_sign_fixed_estimate_is_sign_invariant() {
    // the estimator's quality must not depend on the private sign coins:
    // run the same cluster twice (different worker RNG draws both times
    // would require regenerating; here we assert the weaker, exact
    // invariant: error is invariant under global flip of the estimate)
    propcheck(Config::default().cases(12), "sign invariance", |g| {
        let m = g.usize_in(2, 6);
        let n = g.usize_in(20, 60);
        let seed = g.rng().next_u64();
        let dist = CovModel::paper_fig1(6, 1).gaussian();
        let c = Cluster::generate(&dist, m, n, seed).unwrap();
        let est = SignFixedAverage.run(&c.session()).unwrap();
        let flipped: Vec<f64> = est.w.iter().map(|x| -x).collect();
        let e1 = alignment_error(&est.w, dist.v1());
        let e2 = alignment_error(&flipped, dist.v1());
        assert!((e1 - e2).abs() < 1e-15);
    });
}

#[test]
fn prop_dist_matvec_is_linear_and_symmetric() {
    // routing invariant: the cluster's distributed matvec is a linear,
    // symmetric (self-adjoint) operator — whatever the shard contents
    propcheck(Config::default().cases(10), "dist_matvec linearity", |g| {
        let m = g.usize_in(1, 5);
        let n = g.usize_in(5, 40);
        let d = g.usize_in(2, 10);
        let seed = g.rng().next_u64();
        let dist = CovModel::paper_fig1(d.max(2), 1).gaussian();
        let c = Cluster::generate(&dist, m, n, seed).unwrap();
        let s = c.session();
        let x = g.gaussian_vec(d.max(2));
        let y = g.gaussian_vec(d.max(2));
        let a = g.f64_in(-2.0, 2.0);
        // linearity
        let lhs = s
            .dist_matvec(&x.iter().zip(&y).map(|(xi, yi)| a * xi + yi).collect::<Vec<_>>())
            .unwrap();
        let mx = s.dist_matvec(&x).unwrap();
        let my = s.dist_matvec(&y).unwrap();
        for i in 0..lhs.len() {
            let want = a * mx[i] + my[i];
            assert!((lhs[i] - want).abs() < 1e-9 * (1.0 + want.abs()));
        }
        // symmetry: <y, Mx> == <x, My>
        let s1 = dspca::linalg::vec_ops::dot(&y, &mx);
        let s2 = dspca::linalg::vec_ops::dot(&x, &my);
        assert!((s1 - s2).abs() < 1e-9 * (1.0 + s1.abs()));
    });
}

#[test]
fn prop_one_round_estimators_never_exceed_one_round() {
    propcheck(Config::default().cases(8), "one-round budget", |g| {
        let m = g.usize_in(2, 8);
        let seed = g.rng().next_u64();
        let c = Cluster::generate(&Thm3Dist, m, 30, seed).unwrap();
        for alg in [&NaiveAverage as &dyn Algorithm, &SignFixedAverage, &ProjectionAverage] {
            let est = alg.run(&c.session()).unwrap();
            assert_eq!(est.comm.rounds, 1, "{}", alg.name());
            assert_eq!(est.comm.vectors_gathered, m as u64);
        }
    });
}

#[test]
fn prop_oja_rounds_equal_live_machines() {
    propcheck(Config::default().cases(8), "oja rounds == m", |g| {
        let m = g.usize_in(2, 8);
        let seed = g.rng().next_u64();
        let dist = CovModel::paper_fig1(5, 2).gaussian();
        let c = Cluster::generate(&dist, m, 25, seed).unwrap();
        let est = HotPotatoOja::default().run(&c.session()).unwrap();
        assert_eq!(est.comm.rounds, m as u64);
    });
}

// ---------------------------------------------------------------------
// Block protocol properties (the contract stated in the accounting table
// of `cluster/mod.rs`'s module docs)
// ---------------------------------------------------------------------

fn random_block(g: &mut dspca::propcheck::Gen, d: usize, k: usize) -> Matrix {
    let mut v = Matrix::zeros(d, k);
    for c in 0..k {
        v.set_col(c, &g.gaussian_vec(d));
    }
    v
}

#[test]
fn prop_dist_matmat_column_agrees_with_dist_matvec() {
    // dist_matmat(V) must agree column-for-column with k independent
    // dist_matvec calls, to 1e-12 — including with dead workers
    propcheck(Config::default().cases(10), "dist_matmat column agreement", |g| {
        let m = g.usize_in(1, 5);
        let n = g.usize_in(5, 40);
        let d = g.usize_in(2, 10);
        let k = g.usize_in(1, d);
        let seed = g.rng().next_u64();
        let dist = CovModel::paper_fig1(d, 1).gaussian();
        let c = Cluster::generate(&dist, m, n, seed).unwrap();
        if m > 1 && g.bool() {
            c.kill_worker(g.usize_in(1, m - 1)).unwrap();
        }
        let s = c.session();
        let v = random_block(g, d, k);
        let blk = s.dist_matmat(&v).unwrap();
        for col in 0..k {
            let want = s.dist_matvec(&v.col(col)).unwrap();
            for i in 0..d {
                assert!(
                    (blk.get(i, col) - want[i]).abs() <= 1e-12 * (1.0 + want[i].abs()),
                    "col {col} row {i}: {} vs {}",
                    blk.get(i, col),
                    want[i]
                );
            }
        }
    });
}

#[test]
fn prop_block_round_accounting_matches_module_table() {
    // one dist_matmat: rounds = 1, broadcast = k vectors, gathered =
    // live*k vectors, one request + one response message per live
    // worker, bytes = 8*d*k*(live+1) — exactly the dist_matmat row of
    // the table in cluster/mod.rs
    propcheck(Config::default().cases(10), "block round accounting", |g| {
        let m = g.usize_in(1, 6);
        let d = g.usize_in(2, 12);
        let k = g.usize_in(1, d);
        let seed = g.rng().next_u64();
        let dist = CovModel::paper_fig1(d, 2).gaussian();
        let c = Cluster::generate(&dist, m, 15, seed).unwrap();
        let mut live = m;
        if m > 2 && g.bool() {
            c.kill_worker(1).unwrap();
            live -= 1;
            if m > 3 && g.bool() {
                c.kill_worker(2).unwrap();
                live -= 1;
            }
        }
        let s = c.session();
        let v = random_block(g, d, k);
        s.dist_matmat(&v).unwrap();
        let st = s.stats();
        assert_eq!(st.rounds, 1);
        assert_eq!(st.matvec_products, k as u64);
        assert_eq!(st.vectors_broadcast, k as u64);
        assert_eq!(st.vectors_gathered, (live * k) as u64);
        assert_eq!(st.requests_sent, live as u64);
        assert_eq!(st.responses_received, live as u64);
        assert_eq!(st.bytes, (8 * d * k * (live + 1)) as u64);
    });
}

#[test]
fn prop_block_power_iteration_at_k8_costs_one_round_one_message_per_live_worker() {
    // THE ISSUE-1 acceptance property: one block-power iteration at k = 8
    // costs exactly 1 round and 1 request/response per live worker —
    // where the seed's column-wise loop cost k rounds and k round-trips
    propcheck(Config::default().cases(8), "k=8 block-power iteration cost", |g| {
        let k = 8;
        let m = g.usize_in(2, 6);
        let d = g.usize_in(k, 16);
        let seed = g.rng().next_u64();
        let dist = CovModel::paper_fig1(d, 3).gaussian();
        let c = Cluster::generate(&dist, m, 20, seed).unwrap();
        let mut live = m;
        if m > 2 && g.bool() {
            c.kill_worker(m - 1).unwrap();
            live -= 1;
        }
        let est =
            DistributedOrthoIteration { k, max_iters: 1, tol: 0.0, seed: 0xb, pipeline: true }
                .run_mat(&c.session())
                .unwrap();
        assert_eq!(est.info["iters"], 1.0);
        assert_eq!(est.comm.rounds, 1, "one block iteration must be exactly one round");
        assert_eq!(est.comm.requests_sent, live as u64, "one request per live worker");
        assert_eq!(est.comm.responses_received, live as u64, "one response per live worker");
        assert_eq!(est.comm.vectors_broadcast, k as u64);
        assert_eq!(est.comm.vectors_gathered, (live * k) as u64);
    });
}

#[test]
fn prop_basis_stays_orthonormal_through_block_power_iterations() {
    // after every block-power iteration the leader-side basis satisfies
    // ||W^T W - I||_max < 1e-10
    propcheck(Config::default().cases(8), "block-power orthonormality", |g| {
        let m = g.usize_in(1, 4);
        let d = g.usize_in(3, 12);
        let k = g.usize_in(1, d.min(6));
        let seed = g.rng().next_u64();
        let dist = CovModel::paper_fig1(d, 4).gaussian();
        let c = Cluster::generate(&dist, m, 25, seed).unwrap();
        let s = c.session();
        let (mut w, _) = qr_thin(&random_block(g, d, k));
        for iter in 0..5 {
            let xw = s.dist_matmat(&w).unwrap();
            let (q, _) = qr_thin(&xw);
            let defect = orthonormality_defect(&q);
            assert!(defect < 1e-10, "iteration {iter}: ||W^T W - I||_max = {defect:.3e}");
            w = q;
        }
    });
}

#[test]
fn prop_bytes_equal_encoded_frame_sizes_for_every_collective_and_codec() {
    // THE wire-layer invariant (ISSUE 2 acceptance, extended to the
    // stateful family): for every collective × every codec — lossless,
    // fixed-width, low-bit quantized, sparsified, with and without
    // error feedback — a session's `CommStats.bytes` equals the sum of
    // the materialized frames' sizes: a broadcast frame billed once,
    // one response frame per live worker. Error feedback changes the
    // frames' *contents*, never their size, so the lossy-EF rows assert
    // the same totals as their stateless twins.
    use dspca::cluster::QuantBits;
    propcheck(Config::default().cases(4), "codec-exact byte accounting", |g| {
        let m = g.usize_in(1, 5);
        let n = g.usize_in(5, 25);
        let d = g.usize_in(2, 10);
        let k = g.usize_in(1, d);
        let seed = g.rng().next_u64();
        let dist = CovModel::paper_fig1(d, 5).gaussian();
        let c = Cluster::generate(&dist, m, n, seed).unwrap();
        if m > 1 && g.bool() {
            c.kill_worker(g.usize_in(1, m - 1)).unwrap();
        }
        let live = c.live() as u64;
        let codecs = [
            WireCodec::lossless(),
            WireCodec::new(WirePrecision::F32),
            WireCodec::new(WirePrecision::Bf16),
            WireCodec::quant(QuantBits::Q8),
            WireCodec::quant(QuantBits::Q4),
            WireCodec::quant(QuantBits::Q8).with_feedback(),
            WireCodec::quant(QuantBits::Q4).with_feedback(),
            WireCodec::top_s(2, QuantBits::Q8).with_feedback(),
        ];
        for codec in codecs {
            let s = c.session();
            s.set_codec(codec);
            // the size of one frame of `words` f64 words in `cols`
            // row-major columns — measured on a materialized encoded
            // frame, not assumed from the billing table
            let frame = |words: usize, cols: usize| {
                let payload = vec![0.5; words];
                codec.default_format().encode(&payload, cols).wire_bytes() as u64
            };

            s.dist_matvec(&g.gaussian_vec(d)).unwrap();
            assert_eq!(s.stats().bytes, (live + 1) * frame(d, 1), "{} dist_matvec", codec.label());

            s.reset_stats();
            s.dist_matmat(&random_block(g, d, k)).unwrap();
            assert_eq!(
                s.stats().bytes,
                (live + 1) * frame(d * k, k),
                "{} dist_matmat",
                codec.label()
            );

            s.reset_stats();
            s.local_top_eigvecs(false).unwrap();
            assert_eq!(s.stats().bytes, live * frame(d, 1), "{} local_top_eigvecs", codec.label());

            s.reset_stats();
            s.local_top_k(k).unwrap();
            assert_eq!(s.stats().bytes, live * frame(d * k, k), "{} local_top_k", codec.label());

            s.reset_stats();
            s.gram_average().unwrap();
            assert_eq!(s.stats().bytes, live * frame(d * d, d), "{} gram_average", codec.label());

            s.reset_stats();
            let mut w0 = vec![0.0; d];
            w0[0] = 1.0;
            s.oja_chain(&w0, 0.5, 10.0).unwrap();
            assert_eq!(s.stats().bytes, live * 2 * frame(d, 1), "{} oja_chain", codec.label());
        }
    });
}

#[test]
fn block_estimators_agree_with_each_other_and_centralized() {
    use dspca::coordinator::CentralizedSubspace;
    let (c, _) = fig1(4, 300, 12, 19);
    let k = 3;
    let cen = CentralizedSubspace { k }.run_mat(&c.session()).unwrap();
    let pow = DistributedOrthoIteration::new(k).run_mat(&c.session()).unwrap();
    let lan = BlockLanczos::new(k).run_mat(&c.session()).unwrap();
    assert!(subspace_error(&pow.w, &cen.w) < 1e-8);
    assert!(subspace_error(&lan.w, &cen.w) < 1e-8);
    assert!(subspace_error(&lan.w, &pow.w) < 1e-8);
}

#[test]
fn failure_injection_covers_every_collective() {
    // after kill_worker, every collective — gram_average, local_top_k,
    // oja_chain, dist_matmat (and the already-covered dist_matvec /
    // local_top_eigvecs) — runs over the survivors with exact accounting
    let (c, _) = fig1(6, 80, 8, 29);
    c.kill_worker(2).unwrap();
    c.kill_worker(4).unwrap();
    assert_eq!(c.live(), 4);

    let s: Session<'_> = c.session();
    let g = s.gram_average().unwrap();
    assert_eq!((g.rows(), g.cols()), (8, 8));
    assert_eq!(s.stats().requests_sent, 4);
    assert_eq!(s.stats().vectors_gathered, 4 * 8);

    let s = c.session();
    let locals = s.local_top_k(3).unwrap();
    assert_eq!(locals.len(), 4);
    assert_eq!(s.stats().vectors_gathered, 4 * 3);

    let s = c.session();
    let mut w0 = vec![0.0; 8];
    w0[0] = 1.0;
    let w = s.oja_chain(&w0, 0.5, 10.0).unwrap();
    assert!((norm(&w) - 1.0).abs() < 1e-9);
    assert_eq!(s.stats().rounds, 4, "oja chain visits only live machines");

    let s = c.session();
    let v = Matrix::from_vec(8, 2, (0..16).map(|i| (i as f64 * 0.21).cos()).collect());
    let blk = s.dist_matmat(&v).unwrap();
    assert_eq!(blk.cols(), 2);
    assert_eq!(s.stats().requests_sent, 4);
    // block result equals the survivors' pooled covariance applied to V
    let want = g.matmul(&v);
    assert!(blk.sub(&want).max_abs() < 1e-10);

    // the leader cannot die, ever — even after other failures
    assert!(c.kill_worker(0).is_err());
    assert_eq!(c.live(), 4);

    // and the top-k estimators still run end-to-end over the survivors
    let est = DistributedOrthoIteration::new(2).run_mat(&c.session()).unwrap();
    assert!(orthonormality_defect(&est.w) < 1e-10);
    let lan = BlockLanczos::new(2).run_mat(&c.session()).unwrap();
    assert!(subspace_error(&lan.w, &est.w) < 1e-6);
}

#[test]
fn sni_eps_controls_accuracy() {
    let (c, _) = fig1(4, 400, 16, 13);
    let cen = CentralizedErm.run(&c.session()).unwrap();
    let loose = ShiftInvert::new(SniConfig { eps: 1e-3, ..Default::default() })
        .run(&c.session())
        .unwrap();
    let tight = ShiftInvert::new(SniConfig { eps: 1e-10, ..Default::default() })
        .run(&c.session())
        .unwrap();
    let e_loose = alignment_error(&loose.w, &cen.w);
    let e_tight = alignment_error(&tight.w, &cen.w);
    assert!(e_tight <= 1e-8, "tight run should nail vhat1: {e_tight:.3e}");
    assert!(e_loose <= 1e-1);
    assert!(
        tight.comm.matvec_products >= loose.comm.matvec_products,
        "tighter accuracy cannot be cheaper"
    );
}

// ---------------------------------------------------------------------
// Transport frame properties (the ISSUE 4 satellite): every message
// variant survives the wire bit-for-bit, and the decoder never panics.
// ---------------------------------------------------------------------

/// Propcheck: every `Request`/`Response` variant — error replies and
/// the `CovMatMat` block shapes included — survives whole-message frame
/// encode→decode bit-for-bit under every `WireFormat` (payloads on the
/// format's grid, as the session layer ships them after
/// stream-stepping), the request envelope's `WireDesc` (format +
/// feedback flag + session id) survives verbatim, and decode rejects
/// truncated or length-mismatched frames with an error, never a panic.
#[test]
fn prop_message_frames_roundtrip_bit_for_bit_under_every_codec() {
    use dspca::cluster::{
        decode_request, decode_response, encode_request, encode_response, QuantBits, Request,
        Response, WireDesc, WireFormat,
    };
    propcheck(Config::default().cases(12), "message frame roundtrip", |g| {
        let formats = [
            WireFormat::Plain(WirePrecision::F64),
            WireFormat::Plain(WirePrecision::F32),
            WireFormat::Plain(WirePrecision::Bf16),
            WireFormat::Quant(QuantBits::Q8),
            WireFormat::Quant(QuantBits::Q4),
            WireFormat::TopS { s: 2, bits: QuantBits::Q8 },
        ];
        let format = formats[g.usize_in(0, formats.len() - 1)];
        let desc = WireDesc { format, feedback: g.bool(), sid: g.rng().next_u64() };
        let d = g.usize_in(1, 12);
        let k = g.usize_in(1, 4);
        let seq = g.rng().next_u64();
        // payloads pre-quantized to the format grid at the payload's own
        // column count — exactly what the session layer hands the
        // transport (on-grid values re-encode losslessly)
        let quant = |mut v: Vec<f64>, cols: usize| {
            format.quantize(&mut v, cols);
            v
        };
        let requests = vec![
            Request::CovMatVec(quant(g.gaussian_vec(d), 1)),
            Request::CovMatMat { rows: d, cols: k, data: quant(g.gaussian_vec(d * k), k) },
            Request::LocalTopEigvec { unbiased_signs: g.bool() },
            Request::Gram,
            Request::LocalTopK { k },
            Request::OjaPass {
                w: quant(g.gaussian_vec(d), 1),
                eta0: g.f64_in(0.01, 2.0),
                t0: g.f64_in(1.0, 50.0),
                t_start: g.rng().next_u64() % 100_000,
            },
            Request::Shutdown,
        ];
        for req in &requests {
            let body = encode_request(seq, desc, req);
            let (seq2, desc2, back) = decode_request(&body).unwrap();
            assert_eq!(seq2, seq, "sequence number survives");
            assert_eq!(desc2, desc, "wire descriptor (format, feedback, sid) survives");
            assert_eq!(&back, req, "{} request changed across the wire", format.label());
            // bit-for-bit on the payload words, not just PartialEq
            if let (Some(a), Some(b)) = (req.payload(), back.payload()) {
                for (x, y) in a.iter().zip(b) {
                    assert_eq!(x.to_bits(), y.to_bits());
                }
            }
            // truncation at every cut the generator picks: an error,
            // never a panic
            let cut = g.usize_in(0, body.len() - 1);
            assert!(decode_request(&body[..cut]).is_err(), "prefix of {cut} bytes accepted");
            // trailing garbage is a length mismatch
            let mut longer = body.clone();
            longer.push(0);
            assert!(decode_request(&longer).is_err(), "trailing byte accepted");
        }
        let responses = vec![
            Response::Vector(quant(g.gaussian_vec(d), 1)),
            Response::Mat { rows: d, cols: k, data: quant(g.gaussian_vec(d * k), k) },
            Response::Err(format!("worker {} failed: bad rank", g.usize_in(0, 9))),
        ];
        for resp in &responses {
            let body = encode_response(seq, format, resp);
            let (seq2, fmt2, back) = decode_response(&body).unwrap();
            assert_eq!((seq2, fmt2), (seq, format));
            assert_eq!(&back, resp, "{} response changed across the wire", format.label());
            let cut = g.usize_in(0, body.len() - 1);
            assert!(decode_response(&body[..cut]).is_err());
            let mut longer = body.clone();
            longer.push(0);
            assert!(decode_response(&longer).is_err());
        }
    });
}

#[test]
fn eps_erm_bound_is_respected_in_practice() {
    // Lemma 1's bound is loose but must upper-bound the measured
    // centralized error (sanity of the formula wiring).
    let (c, dist) = fig1(6, 200, 12, 17);
    let est = CentralizedErm.run(&c.session()).unwrap();
    let bound = dist.eps_erm(6, 200, 0.25);
    assert!(est.error(dist.v1()) < bound, "measured error should sit below the Lemma-1 envelope");
}
