//! PJRT-path integration: whole algorithms over PJRT-backed workers and
//! equality against the native path. Skips (with a notice) when
//! `make artifacts` has not been run.

use dspca::cluster::{Cluster, OracleSpec};
use dspca::coordinator::{
    Algorithm, CentralizedErm, DistributedLanczos, HotPotatoOja, ShiftInvert, SignFixedAverage,
};
use dspca::data::{CovModel, Distribution};
use dspca::linalg::vec_ops::alignment_error;
use dspca::runtime::default_artifact_dir;

fn spec() -> Option<OracleSpec> {
    let dir = default_artifact_dir();
    if dir.join("manifest.json").exists() {
        Some(OracleSpec::Pjrt { artifact_dir: dir.to_string_lossy().into_owned() })
    } else {
        eprintln!("skipping PJRT integration: run `make artifacts` first");
        None
    }
}

/// Matches an AOT shape from python/compile/aot.py DEFAULT_SHAPES.
const N: usize = 400;
const D: usize = 64;

#[test]
fn pjrt_and_native_paths_agree_per_algorithm() {
    let Some(pjrt) = spec() else { return };
    let dist = CovModel::paper_fig1(D, 9).gaussian();
    let c_pjrt = Cluster::generate_with(&dist, 3, N, 77, pjrt).unwrap();
    let c_native = Cluster::generate_with(&dist, 3, N, 77, OracleSpec::Native).unwrap();
    let algs: Vec<Box<dyn Algorithm>> = vec![
        Box::new(CentralizedErm),
        Box::new(SignFixedAverage),
        Box::new(DistributedLanczos::default()),
        Box::new(HotPotatoOja::default()),
        Box::new(ShiftInvert::default()),
    ];
    for alg in &algs {
        let a = alg.run(&c_pjrt.session()).unwrap();
        let b = alg.run(&c_native.session()).unwrap();
        let e = alignment_error(&a.w, &b.w);
        assert!(e < 1e-6, "{}: pjrt vs native disagree by {e:.3e}", alg.name());
        assert_eq!(a.comm.rounds, b.comm.rounds, "{}: round counts differ", alg.name());
    }
}

#[test]
fn pjrt_cluster_full_algorithm_accuracy() {
    let Some(pjrt) = spec() else { return };
    let dist = CovModel::paper_fig1(D, 11).gaussian();
    let c = Cluster::generate_with(&dist, 4, N, 13, pjrt).unwrap();
    let cen = CentralizedErm.run(&c.session()).unwrap();
    let sni = ShiftInvert::default().run(&c.session()).unwrap();
    assert!(alignment_error(&sni.w, &cen.w) < 1e-6);
    assert!(cen.error(dist.v1()) < 0.05);
}

#[test]
fn pjrt_smaller_artifact_shape_also_works() {
    let Some(pjrt) = spec() else { return };
    let dist = CovModel::paper_fig1(32, 21).gaussian();
    let c = Cluster::generate_with(&dist, 3, 200, 23, pjrt).unwrap();
    let est = SignFixedAverage.run(&c.session()).unwrap();
    assert!(est.error(dist.v1()) < 0.5);
}
