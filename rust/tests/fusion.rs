//! ISSUE 8 acceptance: **round-fusion billing invariance**.
//!
//! The propcheck property is the fusion analog of the overlap property
//! in `concurrency_stress.rs`: for every codec × backend ×
//! tenant-thread budget, a fleet of tenants whose matvec/matmat rounds
//! coalesce into stacked carrier rounds must end with every per-tenant
//! bill `CommStats`-identical to its solo (unfused) run, the sum of
//! session bills equal to the aggregate window, and results equal to
//! the solo results within summation-order tolerance. A generated
//! dead-worker flag folds the degraded case into the same property:
//! fusion over a shrunken live set must degrade exactly like an
//! unfused round. Mixed-codec displacement and single-member window
//! flushes are pinned by the in-module tests in `cluster/mod.rs`; the
//! TCP mixed-codec regression lives here so both backends are covered.

use std::sync::Barrier;
use std::time::Duration;

use dspca::cluster::{Cluster, CommStats, OracleSpec, QuantBits, WireCodec, WirePrecision};
use dspca::data::CovModel;
use dspca::linalg::Matrix;
use dspca::propcheck::{run as propcheck, Config};
use dspca::transport::{LoopbackWorkers, TransportSpec};

/// DSPCA_PROP_CASES-scalable case count with a test-local default.
fn cases(default: usize) -> usize {
    std::env::var("DSPCA_PROP_CASES").ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

/// One tenant's workload for the property: a fixed query repeated for
/// `ROUNDS` barrier-synced rounds, as a matvec or a k-column matmat.
struct Tenant {
    matmat: bool,
    k: usize,
    query: Matrix,
}

const ROUNDS: usize = 2;

/// THE fusion acceptance property: per-tenant bills and results are
/// fusion-invariant for every codec × backend × tenant-thread budget,
/// with and without a dead worker.
#[test]
fn prop_fused_bills_and_results_match_solo_for_every_codec_backend_and_thread_budget() {
    propcheck(Config::default().cases(cases(8)), "fusion billing invariance", |g| {
        let m = g.usize_in(2, 4);
        let n = g.usize_in(8, 24);
        let d = g.usize_in(3, 10);
        let tenants = g.usize_in(2, 4); // the thread budget under test
        let seed = g.rng().next_u64();
        let prec =
            [WirePrecision::F64, WirePrecision::F32, WirePrecision::Bf16][g.usize_in(0, 2)];
        let tcp = g.bool();
        let kill = m > 2 && g.bool();
        let dist = CovModel::paper_fig1(d, 21).gaussian();

        let fleet: Vec<Tenant> = (0..tenants)
            .map(|_| {
                let matmat = g.bool();
                let k = if matmat { g.usize_in(2, 3) } else { 1 };
                let mut query = Matrix::zeros(d, k);
                for c in 0..k {
                    query.set_col(c, &g.gaussian_vec(d));
                }
                Tenant { matmat, k, query }
            })
            .collect();
        let total_cols: usize = fleet.iter().map(|t| t.k).sum();

        let workers = if tcp { Some(LoopbackWorkers::spawn(m, 1).unwrap()) } else { None };
        let spec = workers.as_ref().map_or(TransportSpec::InProc, |w| w.spec());
        let cluster =
            Cluster::generate_on(&dist, m, n, seed, OracleSpec::Native, &spec).unwrap();
        if kill {
            cluster.kill_worker(m - 1).unwrap();
        }

        // solo references on the quiesced, fusion-free cluster: each
        // tenant's exact workload, bill and result
        let solo: Vec<(CommStats, Matrix)> = fleet
            .iter()
            .map(|t| {
                let s = cluster.session();
                s.set_codec(WireCodec::new(prec));
                let mut out = Matrix::zeros(d, t.k);
                for _ in 0..ROUNDS {
                    out = if t.matmat {
                        s.dist_matmat(&t.query).unwrap()
                    } else {
                        Matrix::from_vec(d, 1, s.dist_matvec(&t.query.col(0)).unwrap())
                    };
                }
                (s.close(), out)
            })
            .collect();

        // fused phase: max_cols is sized so each barrier-synced round
        // forms exactly one full carrier (the last joiner flushes it —
        // no tenant ever waits out the window), making the carrier and
        // member counters deterministic
        cluster.enable_fusion(Duration::from_millis(500), total_cols).unwrap();
        let agg0 = cluster.aggregate_stats();
        let barrier = Barrier::new(tenants);
        let bills: Vec<CommStats> = std::thread::scope(|scope| {
            let handles: Vec<_> = fleet
                .iter()
                .enumerate()
                .map(|(i, t)| {
                    let (cluster, barrier, solo) = (&cluster, &barrier, &solo);
                    scope.spawn(move || {
                        let s = cluster.session();
                        s.set_codec(WireCodec::new(prec));
                        for _ in 0..ROUNDS {
                            barrier.wait();
                            let out = if t.matmat {
                                s.dist_matmat(&t.query).unwrap()
                            } else {
                                Matrix::from_vec(d, 1, s.dist_matvec(&t.query.col(0)).unwrap())
                            };
                            for r in 0..d {
                                for c in 0..t.k {
                                    let want = solo[i].1.get(r, c);
                                    assert!(
                                        (out.get(r, c) - want).abs() < 1e-12,
                                        "tenant {i} entry ({r},{c}): fused {} vs solo {want}",
                                        out.get(r, c)
                                    );
                                }
                            }
                        }
                        s.close()
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });

        let mut sum = CommStats::default();
        for (i, bill) in bills.iter().enumerate() {
            assert_eq!(
                *bill,
                solo[i].0,
                "tenant {i} ({}) under {prec:?}/{}/kill={kill}: fused bill != solo bill",
                if fleet[i].matmat { "matmat" } else { "matvec" },
                spec.label()
            );
            sum.merge(bill);
        }
        assert_eq!(
            cluster.aggregate_stats().delta_since(&agg0),
            sum,
            "{prec:?}/{}: sum of fused session bills != aggregate window",
            spec.label()
        );
        assert_eq!(
            cluster.fusion_counters(),
            (ROUNDS as u64, (ROUNDS * tenants) as u64),
            "{prec:?}/{}: every barrier round must form exactly one full carrier",
            spec.label()
        );
        drop(cluster);
        if let Some(w) = workers {
            w.join().unwrap();
        }
    });
}

/// Regression (TCP side; the in-proc twin lives in `cluster/mod.rs`):
/// sessions on different codecs never share a carrier — the second
/// submit displaces the first batch onto the wire unfused — and each
/// still pays exactly its own codec width.
#[test]
fn tcp_mixed_codec_rounds_never_fuse() {
    let d = 8usize;
    let dist = CovModel::paper_fig1(d, 3).gaussian();
    let workers = LoopbackWorkers::spawn(2, 1).unwrap();
    let cluster =
        Cluster::generate_on(&dist, 2, 20, 7, OracleSpec::Native, &workers.spec()).unwrap();
    cluster.enable_fusion(Duration::from_millis(5), 8).unwrap();
    let a = cluster.session();
    let b = cluster.session();
    b.set_codec(WireCodec::new(WirePrecision::Bf16));
    let v = vec![0.4; d];
    let ta = a.dist_matvec_submit(&v).unwrap();
    let tb = b.dist_matvec_submit(&v).unwrap();
    ta.complete().unwrap();
    tb.complete().unwrap();
    assert_eq!(cluster.fusion_counters(), (0, 0), "mixed codecs must not share a carrier");
    assert_eq!(a.close().bytes, (8 * d * 3) as u64, "lossless bill at 8B/entry");
    assert_eq!(b.close().bytes, (2 * d * 3) as u64, "bf16 bill at 2B/entry");
    drop(cluster);
    workers.join().unwrap();
}

/// Regression (TCP side; the in-proc twin lives in `cluster/mod.rs`):
/// a stateful error-feedback submit entering a fusion window displaces
/// the pending batch — its round never shares a carrier — and both
/// tenants' bills and the EF tenant's residual accumulator come out
/// exactly as in a solo run, shipped through the real socket path.
#[test]
fn tcp_stateful_codec_submits_displace_and_bill_independently() {
    let d = 8usize;
    let dist = CovModel::paper_fig1(d, 3).gaussian();
    let workers = LoopbackWorkers::spawn(2, 1).unwrap();
    let cluster =
        Cluster::generate_on(&dist, 2, 20, 7, OracleSpec::Native, &workers.spec()).unwrap();
    cluster.enable_fusion(Duration::from_millis(200), 8).unwrap();
    let fused = cluster.session();
    let lossy = cluster.session();
    lossy.set_codec(WireCodec::quant(QuantBits::Q4).with_feedback());
    let v = vec![0.4; d];
    let ta = fused.dist_matvec_submit(&v).unwrap();
    let tb = lossy.dist_matvec_submit(&v).unwrap();
    ta.complete().unwrap();
    tb.complete().unwrap();
    assert_eq!(cluster.fusion_counters(), (0, 0), "stateful codecs must never share a carrier");
    // solo frame arithmetic, untouched by the fused neighbor: a Q4
    // frame on 8 words, 1 column = 4 (scale) + 4 (nibble) bytes,
    // billed once per live worker plus the leader broadcast
    assert!(lossy.residual_norm() > 0.0, "the EF stream accumulated the Q4 drop");
    assert_eq!(fused.residual_norm(), 0.0, "stateless tenant keeps no stream");
    assert_eq!(lossy.close().bytes, ((4 + 4) * 3) as u64, "EF tenant bills its own frames");
    assert_eq!(fused.close().bytes, (8 * d * 3) as u64, "displaced tenant bills solo frames");
    drop(cluster);
    workers.join().unwrap();
}
