//! ISSUE 4 acceptance: the cluster over real TCP loopback sockets.
//!
//! Boots TCP workers on ephemeral localhost ports (thread-hosted
//! `serve_worker` loops — the same code `dspca worker --listen` runs —
//! plus one test that spawns actual `dspca worker` **processes**) and
//! asserts the transport contract: same seed ⇒ same estimates and a
//! `CommStats` bill identical (rounds, messages, bytes) to the in-proc
//! run, for the power method, the block collective, a figure1-style
//! sweep, and the concurrent lossless+bf16 two-tenant serve invariant.

use dspca::cluster::{Cluster, CommStats, OracleSpec, WireCodec, WirePrecision};
use dspca::coordinator::{Algorithm, DistributedPower, QuantizedPower, SignFixedAverage};
use dspca::data::{CovModel, Distribution};
use dspca::linalg::Matrix;
use dspca::propcheck::{run as propcheck, Config};
use dspca::serve::{serve, Job};
use dspca::transport::{LoopbackWorkers, TransportSpec};

fn fig1_dist(d: usize, seed: u64) -> impl Distribution {
    CovModel::paper_fig1(d, seed).gaussian()
}

/// THE acceptance test: 3 TCP workers on ephemeral localhost ports run
/// `DistributedPower` and one block (`dist_matmat`) collective with a
/// bill equal to the in-proc bill for the same seed — and bit-identical
/// numerics.
#[test]
fn three_tcp_workers_match_inproc_bills_for_power_and_block_collective() {
    let (d, m, n, seed) = (10usize, 3usize, 80usize, 0x7c1u64);
    let dist = fig1_dist(d, 3);
    let block = Matrix::from_vec(d, 2, (0..2 * d).map(|i| (i as f64 * 0.3).sin()).collect());

    let inproc = Cluster::generate(&dist, m, n, seed).unwrap();
    assert_eq!(inproc.transport_name(), "inproc");
    let ref_power = DistributedPower::default().run(&inproc.session()).unwrap();
    let s = inproc.session();
    let ref_block = s.dist_matmat(&block).unwrap();
    let ref_block_bill = s.close();
    drop(inproc);

    let workers = LoopbackWorkers::spawn(m, 1).unwrap();
    let tcp =
        Cluster::generate_on(&dist, m, n, seed, OracleSpec::Native, &workers.spec()).unwrap();
    assert_eq!(tcp.transport_name(), "tcp");
    let tcp_power = DistributedPower::default().run(&tcp.session()).unwrap();
    assert_eq!(tcp_power.comm, ref_power.comm, "power bill must be backend-invariant");
    assert_eq!(tcp_power.w, ref_power.w, "power estimate must be bit-identical over TCP");
    let s = tcp.session();
    let tcp_block = s.dist_matmat(&block).unwrap();
    assert_eq!(tcp_block.data(), ref_block.data(), "block result bit-identical over TCP");
    assert_eq!(s.close(), ref_block_bill, "block bill identical over TCP");
    drop(tcp);
    workers.join().unwrap();
}

/// A figure1-style sweep over TCP loopback produces the identical CSV:
/// the leader reconnects to the same worker set for every run's
/// cluster, and every estimator (including the sign-randomized ones —
/// worker coins ship with the handshake seed) reproduces in-proc.
#[test]
fn figure1_style_sweep_over_tcp_matches_inproc_csv() {
    use dspca::experiments::figure1::{run, Fig1Config, Fig1Dist};
    let mut cfg = Fig1Config {
        d: 8,
        m: 3,
        n_list: vec![30],
        runs: 2,
        seed: 11,
        dist: Fig1Dist::Gaussian,
        oracle: OracleSpec::Native,
        transport: TransportSpec::InProc,
    };
    let reference = run(&cfg).unwrap().render();
    // runs × |n_list| clusters connect in sequence: 2 leader
    // connections per worker
    let workers = LoopbackWorkers::spawn(3, 2).unwrap();
    cfg.transport = workers.spec();
    let over_tcp = run(&cfg).unwrap().render();
    assert_eq!(over_tcp, reference, "figure1 CSV must be identical over TCP loopback");
    workers.join().unwrap();
}

/// The two-tenant serve invariant on TCP: a lossless and a bf16 tenant
/// running concurrently through the scheduler each bill exactly their
/// solo in-proc bill, and Σ bills == the aggregate window.
#[test]
fn concurrent_lossless_and_bf16_tenants_bill_like_solo_on_tcp() {
    let (d, m, n, seed) = (10usize, 3usize, 80usize, 0x5eu64);
    let dist = fig1_dist(d, 7);
    let inproc = Cluster::generate(&dist, m, n, seed).unwrap();
    let solo_power = DistributedPower::default().run(&inproc.session()).unwrap();
    let solo_quant = QuantizedPower::new(WirePrecision::Bf16).run(&inproc.session()).unwrap();
    assert!(solo_power.comm.bytes > 0 && solo_quant.comm.bytes > 0);
    drop(inproc);

    let workers = LoopbackWorkers::spawn(m, 1).unwrap();
    let tcp =
        Cluster::generate_on(&dist, m, n, seed, OracleSpec::Native, &workers.spec()).unwrap();
    let agg0 = tcp.aggregate_stats();
    let report = serve(
        &tcp,
        vec![
            Job::new("lossless-power", Box::new(DistributedPower::default())),
            Job::new("bf16-power", Box::new(QuantizedPower::new(WirePrecision::Bf16))),
        ],
        2,
    )
    .unwrap();
    for j in &report.jobs {
        assert!(j.succeeded(), "{}: {:?}", j.name, j.error);
    }
    assert_eq!(report.jobs[0].comm, solo_power.comm, "lossless tenant bill on TCP");
    assert_eq!(report.jobs[1].comm, solo_quant.comm, "bf16 tenant bill on TCP");
    assert!(report.accounting_exact, "Σ job bills must equal the aggregate window");
    assert_eq!(tcp.aggregate_stats().delta_since(&agg0), report.aggregate);
    drop(report);
    drop(tcp);
    workers.join().unwrap();
}

/// Propcheck: every collective × a random codec bills identically —
/// and returns identical numbers — on both backends.
#[test]
fn prop_every_collective_bills_identically_on_both_backends() {
    propcheck(Config::default().cases(4), "transport bill invariance", |g| {
        let m = g.usize_in(1, 3);
        let n = g.usize_in(5, 20);
        let d = g.usize_in(2, 8);
        let k = g.usize_in(1, d);
        let seed = g.rng().next_u64();
        let prec = [WirePrecision::F64, WirePrecision::F32, WirePrecision::Bf16]
            [g.usize_in(0, 2)];
        let dist = fig1_dist(d, 9);
        let payload = g.gaussian_vec(d);
        let mut block = Matrix::zeros(d, k);
        for col in 0..k {
            block.set_col(col, &payload);
        }
        let run_all = |spec: &TransportSpec| -> (CommStats, Vec<f64>) {
            let c = Cluster::generate_on(&dist, m, n, seed, OracleSpec::Native, spec).unwrap();
            let s = c.session();
            s.set_codec(WireCodec::new(prec));
            let x = s.dist_matvec(&payload).unwrap();
            s.dist_matmat(&block).unwrap();
            s.local_top_eigvecs(true).unwrap();
            s.local_top_k(k).unwrap();
            s.gram_average().unwrap();
            s.oja_chain(&payload, 0.5, 10.0).unwrap();
            (s.close(), x)
        };
        let (inproc_bill, inproc_x) = run_all(&TransportSpec::InProc);
        let workers = LoopbackWorkers::spawn(m, 1).unwrap();
        let (tcp_bill, tcp_x) = run_all(&workers.spec());
        workers.join().unwrap();
        assert_eq!(inproc_bill, tcp_bill, "bills must be backend-invariant ({prec:?})");
        assert_eq!(inproc_x, tcp_x, "collective numerics must be backend-invariant");
    });
}

/// The multi-process deployment itself: N real `dspca worker --listen`
/// **processes** (`--once`), a leader in this process, identical bill
/// and estimate to in-proc, clean worker exit after the leader drops.
#[test]
fn real_worker_processes_complete_a_run_with_the_inproc_bill() {
    use std::io::{BufRead, BufReader};
    use std::process::{Child, Command, Stdio};
    let (d, m, n, seed) = (8usize, 2usize, 60usize, 0xabcu64);
    let bin = env!("CARGO_BIN_EXE_dspca");
    let mut children: Vec<Child> = Vec::new();
    let mut pipes = Vec::new(); // keep stdout pipes open for the workers' lifetime
    let mut addrs: Vec<String> = Vec::new();
    for _ in 0..m {
        let mut child = Command::new(bin)
            .args(["worker", "--listen", "127.0.0.1:0", "--once"])
            .stdout(Stdio::piped())
            .stderr(Stdio::null())
            .spawn()
            .expect("spawning a dspca worker process");
        // first stdout line: "dspca worker listening on 127.0.0.1:PORT"
        let mut reader = BufReader::new(child.stdout.take().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let addr = line.trim().rsplit(' ').next().unwrap_or_default().to_string();
        assert!(addr.contains(':'), "worker did not report its address: {line:?}");
        addrs.push(addr);
        children.push(child);
        pipes.push(reader);
    }

    let dist = fig1_dist(d, 3);
    let inproc = Cluster::generate(&dist, m, n, seed).unwrap();
    let want = SignFixedAverage.run(&inproc.session()).unwrap();
    drop(inproc);

    let spec = TransportSpec::tcp(addrs);
    let tcp = Cluster::generate_on(&dist, m, n, seed, OracleSpec::Native, &spec).unwrap();
    let got = SignFixedAverage.run(&tcp.session()).unwrap();
    assert_eq!(got.comm, want.comm, "process-level TCP bill == in-proc bill");
    assert_eq!(got.w, want.w, "process-level TCP estimate == in-proc estimate");
    drop(tcp); // sends Shutdown; each --once worker then exits

    for mut child in children {
        let status = child.wait().unwrap();
        assert!(status.success(), "worker process exited with {status}");
    }
}

/// An unreachable worker is a clean construction error naming the peer
/// and its address — not a hang, not a panic.
#[test]
fn unreachable_worker_is_a_clean_error_naming_the_peer() {
    let addr = {
        // bind-then-drop to obtain a port with no listener behind it
        let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        l.local_addr().unwrap().to_string()
    };
    let dist = fig1_dist(6, 1);
    let spec = TransportSpec::tcp(vec![addr.clone()]);
    let err = Cluster::generate_on(&dist, 1, 20, 5, OracleSpec::Native, &spec)
        .map(|_| ())
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("worker 0"), "{msg}");
    assert!(msg.contains(&addr), "{msg}");
}
