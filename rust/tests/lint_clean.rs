//! The repo-invariant lint gate, as an integration test: the committed
//! tree must produce zero findings from `dspca lint`. This is the same
//! scan the CI `lint` job runs via the CLI — having it in `cargo test`
//! means a violation fails the ordinary test suite too, not just a
//! separate CI job someone might not run locally.
//!
//! The rules (see `src/analysis/lint.rs` for the full statement):
//! 1. `CommStats` fields are mutated only in `cluster/comm.rs` and
//!    `cluster/session.rs` — the billing surface stays auditable.
//! 2. No `unwrap()`/`expect(` in non-test `src/` beyond each file's
//!    explicit budget.
//! 3. `std::env::set_var` only inside the bench-harness guard.
//! 4. Every `cmd_*` in `main.rs` validates its flags via
//!    `ensure_known_flags`.
//! 5. No raw `std::sync::Mutex`/`Condvar` outside `src/sync/` — all
//!    locks go through the instrumented shim.

use dspca::analysis::lint;

#[test]
fn the_committed_tree_passes_the_repo_invariant_lint() {
    let root = lint::default_root();
    let findings = lint::run(&root).expect("lint scan must not error");
    assert!(
        findings.is_empty(),
        "repo-invariant lint found {} violation(s):\n{}",
        findings.len(),
        findings.iter().map(|f| format!("  {f}\n")).collect::<String>()
    );
}
