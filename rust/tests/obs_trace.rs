//! ISSUE 9 acceptance: the trace is a correctness oracle for the bill.
//!
//! Runs a multi-tenant workload over real TCP loopback sockets with the
//! in-memory trace sink installed, then proves — twice, once through
//! `obs::report::crosscheck` and once by independently re-summing the
//! raw JSONL — that Σ traced bytes per session equals that session's
//! closing `CommStats` bill, and that the Chrome export of the same
//! lines passes the in-tree schema validator.
//!
//! One `#[test]` on purpose: the trace sink is process-global, and the
//! harness runs a binary's tests concurrently — a second test
//! installing a sink would race this one's capture.

use dspca::cluster::{Cluster, CommStats, OracleSpec, WireCodec, WirePrecision};
use dspca::coordinator::{DistributedPower, QuantizedPower};
use dspca::data::CovModel;
use dspca::obs::{report, trace};
use dspca::serve::{serve, Job};
use dspca::transport::LoopbackWorkers;
use dspca::util::json::Json;

#[test]
fn traced_bytes_mirror_every_closed_sessions_bill_over_tcp() {
    let (d, m, n, seed) = (10usize, 3usize, 80usize, 0x0b5u64);
    let dist = CovModel::paper_fig1(d, 5).gaussian();

    trace::install_memory();
    let workers = LoopbackWorkers::spawn(m, 1).unwrap();
    let cluster =
        Cluster::generate_on(&dist, m, n, seed, OracleSpec::Native, &workers.spec()).unwrap();

    // tenant 1: a directly-driven session with a lossy codec and an
    // explicit timeline label
    let s = cluster.session();
    s.set_trace_label("direct-bf16");
    s.set_codec(WireCodec::new(WirePrecision::Bf16));
    let v = dspca::rng::Pcg64::new(9).gaussian_vec(d);
    s.dist_matvec(&v).unwrap();
    s.gram_average().unwrap();
    let direct_sid = s.sid();
    let direct_bill = s.close();
    assert!(direct_bill.bytes > 0 && direct_bill.rounds > 0);

    // tenant 1b: a stateful lossy error-feedback stream — its traced
    // rows must carry the materialized q4 frame sizes, shipped through
    // the worker-side ReplyBank over the real socket
    let ef = cluster.session();
    ef.set_trace_label("direct-q4ef");
    ef.set_codec(WireCodec::quant(dspca::cluster::QuantBits::Q4).with_feedback());
    for _ in 0..3 {
        ef.dist_matvec(&v).unwrap();
    }
    let ef_sid = ef.sid();
    let ef_bill = ef.close();
    // q4 frames: (4-byte scale + ⌈d/2⌉ nibble bytes)·(live+1) per round
    assert_eq!(ef_bill.bytes, ef_bill.rounds * ((4 + d as u64 / 2) * (m as u64 + 1)));

    // tenants 2 and 3: concurrent jobs through the scheduler, which
    // labels and closes their sessions itself
    let served = serve(
        &cluster,
        vec![
            Job::new("lossless-power", Box::new(DistributedPower::default())),
            Job::new("bf16-power", Box::new(QuantizedPower::new(WirePrecision::Bf16))),
        ],
        2,
    )
    .unwrap();
    for j in &served.jobs {
        assert!(j.succeeded(), "{}: {:?}", j.name, j.error);
    }

    // tenants 4 and 5: barrier-synced rounds with round fusion on, so
    // the wire ships stacked carriers while each member is billed (and
    // traced) exactly its solo bytes — the acceptance shape: the
    // cross-check on a multi-tenant *fused* TCP run
    assert_eq!(cluster.fusion_counters(), (0, 0));
    cluster.enable_fusion(std::time::Duration::from_millis(500), 2).unwrap();
    let barrier = std::sync::Barrier::new(2);
    let fused: Vec<(u64, CommStats)> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..2)
            .map(|i| {
                let (cluster, barrier, v) = (&cluster, &barrier, &v);
                scope.spawn(move || {
                    let s = cluster.session();
                    s.set_trace_label(&format!("fused-tenant-{i}"));
                    for _ in 0..3 {
                        // per-iteration sync keeps every 2-column batch full
                        barrier.wait();
                        s.dist_matvec(v).unwrap();
                    }
                    (s.sid(), s.close())
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    assert_eq!(cluster.fusion_counters(), (3, 6), "every round must have fused");
    assert_eq!(fused[0].1, fused[1].1, "identical fused workloads, identical bills");
    assert!(fused[0].1.bytes > 0);

    // every emitting thread must be gone before finish(): scheduler
    // threads exited inside serve(), the reactor exits with the
    // cluster, the worker threads with join()
    drop(cluster);
    workers.join().unwrap();
    let lines = trace::finish().unwrap().expect("memory sink returns captured lines");
    assert!(!lines.is_empty(), "the run must have produced trace events");

    // oracle #1: the report's own cross-check over all closed sessions
    let rep = report::parse_lines(lines.iter().map(String::as_str)).unwrap();
    let checked = rep.crosscheck().unwrap();
    assert!(checked >= 6, "6 sessions closed, {checked} cross-checked");

    // the fused tenants' rows specifically must carry fused_submit
    // bytes that reproduce their bills
    for (sid, bill) in &fused {
        let row = rep.sessions.iter().find(|r| r.sid == *sid).expect("fused session row");
        assert_eq!(row.check(), Some(true), "fused session {sid} mismatched");
        assert_eq!(row.traced_bytes, bill.bytes);
        assert_eq!(row.traced_rounds, bill.rounds);
    }

    // oracle #2: re-sum the raw JSONL for the directly-driven sessions
    // without going through TraceReport, and compare against the bill
    // returned by close() — two independently-plumbed ledgers, one
    // total. Run it for both the stateless bf16 tenant and the
    // stateful q4+feedback tenant: the EF stream's traced frames must
    // sum to its bill exactly like any other codec's.
    let resum = |sid: u64| {
        let (mut sum_bytes, mut sum_rounds) = (0u64, 0u64);
        let mut billed: Option<(u64, u64)> = None;
        for line in &lines {
            let j = Json::parse(line).unwrap();
            if j.get("sid").and_then(|v| v.as_f64()).map(|v| v as u64) != Some(sid) {
                continue;
            }
            let ev = j.get("ev").and_then(|v| v.as_str()).unwrap();
            let bytes = j.get("bytes").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64;
            match ev {
                "submit" | "fused_submit" => {
                    sum_bytes += bytes;
                    if bytes > 0 {
                        sum_rounds += 1;
                    }
                }
                "reply" => sum_bytes += bytes,
                "session_bill" => {
                    let rounds =
                        j.get("rounds").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64;
                    billed = Some((bytes, rounds));
                }
                _ => {}
            }
        }
        (sum_bytes, sum_rounds, billed)
    };
    let (sum_bytes, sum_rounds, billed) = resum(direct_sid);
    assert_eq!(billed, Some((direct_bill.bytes, direct_bill.rounds)));
    assert_eq!(sum_bytes, direct_bill.bytes, "sigma traced bytes == CommStats.bytes");
    assert_eq!(sum_rounds, direct_bill.rounds, "sigma traced rounds == CommStats.rounds");
    let (ef_bytes, ef_rounds, ef_billed) = resum(ef_sid);
    assert_eq!(ef_billed, Some((ef_bill.bytes, ef_bill.rounds)));
    assert_eq!(ef_bytes, ef_bill.bytes, "sigma traced bytes == the EF tenant's bill");
    assert_eq!(ef_rounds, ef_bill.rounds);

    // the serve tenants' bills appear verbatim as their session_bill events
    for job in &served.jobs {
        let found = lines.iter().any(|l| {
            let j = Json::parse(l).unwrap();
            j.get("ev").and_then(|v| v.as_str()) == Some("session_bill")
                && j.get("bytes").and_then(|v| v.as_f64()) == Some(job.comm.bytes as f64)
                && j.get("rounds").and_then(|v| v.as_f64()) == Some(job.comm.rounds as f64)
        });
        assert!(found, "{}: bill {:?} missing from the trace", job.name, job.comm);
    }

    // the rendered timeline names the labeled tenant and prints the verdict
    let text = rep.render();
    assert!(text.contains("direct-bf16"), "timeline must name the tenant:\n{text}");
    assert!(text.contains("direct-q4ef"), "timeline must name the EF tenant:\n{text}");
    assert!(text.contains("cross-check:"), "footer missing:\n{text}");
    assert!(!text.contains("MISMATCH"), "no session may mismatch:\n{text}");

    // the Chrome export of the same lines is schema-valid and non-empty
    let chrome = report::chrome_export(lines.iter().map(String::as_str)).unwrap();
    report::validate_chrome(&chrome).unwrap();
    let n_events =
        chrome.get("traceEvents").and_then(|e| e.as_arr()).map(Vec::len).unwrap_or(0);
    assert!(n_events > 0, "chrome export must carry the run's events");
}
