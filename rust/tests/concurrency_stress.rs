//! ISSUE 5 acceptance: billing invariants under **genuine overlap**,
//! plus the split-phase wall-clock wins, at stress size.
//!
//! The propcheck property here is the concurrency analog of the wire
//! accounting table: for every collective × codec × backend, running
//! the collective while another session's ticket is in flight (so the
//! collective's completer routes the other tenant's replies as the
//! driver) must leave every bill identical to its solo run and the sum
//! of session bills equal to the aggregate window.
//!
//! The wall-clock gates (E11 serve overlap at 4 tenants, E12 pipelined
//! rounds over TCP) run in measurement mode everywhere and as hard
//! `ensure!` gates when `DSPCA_STRESS=1` — the release-mode CI
//! concurrency job sets it; plain `cargo test` on an arbitrary
//! dev laptop does not gate on its core count.

use std::sync::atomic::{AtomicUsize, Ordering};

use dspca::cluster::{Cluster, CommStats, OracleSpec, Session, WireCodec, WirePrecision};
use dspca::data::CovModel;
use dspca::linalg::Matrix;
use dspca::propcheck::{run as propcheck, Config};
use dspca::transport::{LoopbackWorkers, TransportSpec};

/// DSPCA_PROP_CASES-scalable case count with a test-local default.
fn cases(default: usize) -> usize {
    std::env::var("DSPCA_PROP_CASES").ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

/// Whether the wall-clock gates are hard errors (the CI stress job).
fn gated() -> bool {
    std::env::var("DSPCA_STRESS").as_deref() == Ok("1")
}

const COLLECTIVES: [&str; 6] =
    ["dist_matvec", "dist_matmat", "local_top_eigvecs", "local_top_k", "gram_average", "oja_chain"];

fn run_collective(s: &Session<'_>, which: &str, v: &[f64], block: &Matrix, k: usize) {
    match which {
        "dist_matvec" => {
            s.dist_matvec(v).unwrap();
        }
        "dist_matmat" => {
            s.dist_matmat(block).unwrap();
        }
        "local_top_eigvecs" => {
            s.local_top_eigvecs(false).unwrap();
        }
        "local_top_k" => {
            s.local_top_k(k).unwrap();
        }
        "gram_average" => {
            s.gram_average().unwrap();
        }
        "oja_chain" => {
            s.oja_chain(v, 0.5, 10.0).unwrap();
        }
        other => panic!("unknown collective {other}"),
    }
}

/// THE overlap-billing acceptance property: every collective × codec ×
/// backend, with tickets from two sessions genuinely in flight at once.
#[test]
fn prop_bills_survive_overlap_for_every_collective_codec_and_backend() {
    propcheck(Config::default().cases(cases(8)), "overlap billing invariance", |g| {
        let m = g.usize_in(2, 4);
        let n = g.usize_in(8, 24);
        let d = g.usize_in(3, 10);
        let k = g.usize_in(1, d);
        let seed = g.rng().next_u64();
        let prec =
            [WirePrecision::F64, WirePrecision::F32, WirePrecision::Bf16][g.usize_in(0, 2)];
        let tcp = g.bool();
        let dist = CovModel::paper_fig1(d, 21).gaussian();
        let v = g.gaussian_vec(d);
        let mut block = Matrix::zeros(d, k);
        for c in 0..k {
            block.set_col(c, &v);
        }

        let workers = if tcp { Some(LoopbackWorkers::spawn(m, 1).unwrap()) } else { None };
        let spec = workers.as_ref().map_or(TransportSpec::InProc, |w| w.spec());
        let cluster =
            Cluster::generate_on(&dist, m, n, seed, OracleSpec::Native, &spec).unwrap();

        for which in COLLECTIVES {
            // solo reference bills on the quiesced cluster
            let solo = {
                let s = cluster.session();
                s.set_codec(WireCodec::new(prec));
                run_collective(&s, which, &v, &block, k);
                s.close()
            };
            let solo_probe = {
                let s = cluster.session();
                s.dist_matvec(&v).unwrap();
                s.close()
            };
            // overlapped: a lossless tenant's ticket stays open across
            // the whole collective, so the collective's completer
            // routes (and bills) the other tenant's replies as the
            // router driver
            let agg0 = cluster.aggregate_stats();
            let holder = cluster.session();
            let ticket = holder.dist_matvec_submit(&v).unwrap();
            let s = cluster.session();
            s.set_codec(WireCodec::new(prec));
            run_collective(&s, which, &v, &block, k);
            ticket.complete().unwrap();
            let (bill, holder_bill) = (s.close(), holder.close());
            assert_eq!(
                bill, solo,
                "{which} under {prec:?}/{}: overlapped bill != solo bill",
                spec.label()
            );
            assert_eq!(
                holder_bill, solo_probe,
                "{which} under {prec:?}/{}: open ticket's bill != solo bill",
                spec.label()
            );
            let mut sum = bill;
            sum.merge(&holder_bill);
            assert_eq!(
                cluster.aggregate_stats().delta_since(&agg0),
                sum,
                "{which} under {prec:?}/{}: sum of session bills != aggregate window",
                spec.label()
            );
        }
        drop(cluster);
        if let Some(w) = workers {
            w.join().unwrap();
        }
    });
}

/// Many tenant threads, every one keeping several tickets of its own in
/// flight, racing on one cluster: per-session bills stay exactly
/// per-round predictable and sum to the aggregate window.
#[test]
fn hammered_router_keeps_every_ledger_exact() {
    let threads = 6usize;
    let rounds = 24usize;
    let depth = 3usize;
    let d = 12usize;
    let dist = CovModel::paper_fig1(d, 9).gaussian();
    let cluster = Cluster::generate(&dist, 4, 40, 0xc0ffee).unwrap();
    let agg0 = cluster.aggregate_stats();
    let finished = AtomicUsize::new(0);
    let bills: Vec<CommStats> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..threads)
            .map(|i| {
                let cluster = &cluster;
                let finished = &finished;
                scope.spawn(move || {
                    let s = cluster.session();
                    if i % 2 == 1 {
                        s.set_codec(WireCodec::new(WirePrecision::Bf16));
                    }
                    let v = vec![0.25 + i as f64; d];
                    let mut window = std::collections::VecDeque::new();
                    for _ in 0..rounds {
                        window.push_back(s.dist_matvec_submit(&v).unwrap());
                        if window.len() >= depth {
                            window.pop_front().unwrap().complete().unwrap();
                        }
                    }
                    while let Some(t) = window.pop_front() {
                        t.complete().unwrap();
                    }
                    drop(window);
                    finished.fetch_add(1, Ordering::Relaxed);
                    s.close()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    assert_eq!(finished.load(Ordering::Relaxed), threads, "no tenant thread wedged");
    let mut sum = CommStats::default();
    for (i, b) in bills.iter().enumerate() {
        let bpe = if i % 2 == 1 { 2 } else { 8 };
        assert_eq!(b.rounds, rounds as u64, "tenant {i} round count");
        assert_eq!(b.requests_sent, (rounds * 4) as u64, "tenant {i} requests");
        assert_eq!(b.responses_received, (rounds * 4) as u64, "tenant {i} responses");
        assert_eq!(b.bytes, (rounds * bpe * d * 5) as u64, "tenant {i} B(d)·(live+1) bytes");
        sum.merge(b);
    }
    assert_eq!(cluster.aggregate_stats().delta_since(&agg0), sum, "aggregate identity");
}

/// E11 wall-clock: the serve batch at 4 tenants vs 1 on the Fig-1 job
/// mix. Always measured; a hard `<= 0.7x` gate under DSPCA_STRESS=1
/// (the release-mode CI concurrency job).
#[test]
fn serve_overlap_win_at_four_tenants() {
    use dspca::experiments::serve::{run, ServeConfig};
    let cfg = ServeConfig {
        d: 40,
        m: 6,
        n: 300,
        jobs: 12,
        tenants_list: vec![1, 4],
        assert_overlap: if gated() { Some(0.7) } else { None },
        ..Default::default()
    };
    let table = run(&cfg).unwrap();
    let rendered = table.render();
    // surface the measured ratio either way so CI logs carry the trend
    println!("serve overlap sweep:\n{rendered}");
    assert_eq!(rendered.lines().count(), 3, "header + one row per tenant count");
}

/// E12 wall-clock: pipelined rounds vs serialized rounds on TCP
/// loopback. Always measured; hard-gated under DSPCA_STRESS=1.
#[test]
fn pipelined_rounds_beat_serialized_rounds_on_tcp_loopback() {
    use dspca::experiments::transport::{run, TransportConfig};
    let cfg = TransportConfig {
        d_list: vec![64],
        m: 4,
        n: 100,
        rounds: 48,
        assert_pipeline_win: gated(),
        ..Default::default()
    };
    let table = run(&cfg).unwrap();
    println!("transport pipeline sweep:\n{}", table.render());
}

/// E12 reactor acceptance (ISSUE 8): 64 loopback TCP peers served by
/// at most one leader-side reader thread, with bills bit-identical to
/// in-proc. Both `ensure!`s inside the driver are structural, so this
/// runs ungated at full acceptance size.
#[test]
fn reactor_serves_64_peers_with_one_reader_thread() {
    use dspca::experiments::transport::{run_reactor, ReactorConfig};
    let table = run_reactor(&ReactorConfig::default()).unwrap();
    println!("reactor gate:\n{}", table.render());
}

/// ISSUE 9 acceptance: observation is bill-invariant. The flight
/// recorder's metrics are always on, so every billing assertion in this
/// file already runs with them; this property closes the remaining gap
/// by flipping **tracing** on and proving that, for random codec ×
/// backend × tenant-thread-count, every session's bill and every
/// collective's numerics are bit-identical to the untraced run — and
/// that the captured trace passes the Σ-traced-bytes == bill
/// cross-check for each of our sessions. (The cross-check is scoped to
/// our own sids: the sink is process-global, so sessions belonging to
/// concurrently-running tests may appear in the capture mid-flight.)
#[test]
fn prop_observability_leaves_every_bill_and_estimate_bit_identical() {
    propcheck(Config::default().cases(cases(6)), "obs bill invariance", |g| {
        let m = g.usize_in(2, 3);
        let n = g.usize_in(8, 20);
        let d = g.usize_in(3, 8);
        let threads = g.usize_in(1, 3);
        let seed = g.rng().next_u64();
        let prec =
            [WirePrecision::F64, WirePrecision::F32, WirePrecision::Bf16][g.usize_in(0, 2)];
        let tcp = g.bool();
        let dist = CovModel::paper_fig1(d, 13).gaussian();
        let v = g.gaussian_vec(d);

        // one run = `threads` tenants on a fresh cluster, each closing
        // its own session; returns per-tenant (bill, result, sid) in
        // thread order plus the captured trace when tracing was on
        let run_once = |traced: bool| {
            let workers = if tcp { Some(LoopbackWorkers::spawn(m, 1).unwrap()) } else { None };
            let spec = workers.as_ref().map_or(TransportSpec::InProc, |w| w.spec());
            let cluster =
                Cluster::generate_on(&dist, m, n, seed, OracleSpec::Native, &spec).unwrap();
            if traced {
                dspca::obs::trace::install_memory();
            }
            let per_tenant: Vec<(CommStats, Vec<f64>, u64)> = std::thread::scope(|scope| {
                let handles: Vec<_> = (0..threads)
                    .map(|i| {
                        let cluster = &cluster;
                        let v = &v;
                        scope.spawn(move || {
                            let s = cluster.session();
                            s.set_trace_label(&format!("prop-tenant-{i}"));
                            s.set_codec(WireCodec::new(prec));
                            let x = s.dist_matvec(v).unwrap();
                            s.gram_average().unwrap();
                            let sid = s.sid();
                            (s.close(), x, sid)
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).collect()
            });
            drop(cluster);
            if let Some(w) = workers {
                w.join().unwrap();
            }
            let lines = if traced { dspca::obs::trace::finish().unwrap() } else { None };
            (per_tenant, lines)
        };

        let (plain, no_lines) = run_once(false);
        let (traced, lines) = run_once(true);
        assert!(no_lines.is_none());
        for (i, ((pb, px, _), (tb, tx, _))) in plain.iter().zip(&traced).enumerate() {
            assert_eq!(
                tb, pb,
                "tenant {i} under {prec:?}/tcp={tcp}/threads={threads}: traced bill != plain"
            );
            assert_eq!(
                tx, px,
                "tenant {i} under {prec:?}/tcp={tcp}/threads={threads}: traced result != plain"
            );
        }
        // and the capture itself is a faithful mirror of our bills
        let lines = lines.expect("traced run must return the memory capture");
        let rep = dspca::obs::report::parse_lines(lines.iter().map(String::as_str)).unwrap();
        for (_, _, sid) in &traced {
            let row = rep
                .sessions
                .iter()
                .find(|r| r.sid == *sid)
                .unwrap_or_else(|| panic!("session {sid} missing from the trace"));
            assert_eq!(
                row.check(),
                Some(true),
                "session {sid}: traced {}B/{}r vs billed {:?}B/{:?}r",
                row.traced_bytes,
                row.traced_rounds,
                row.bill_bytes,
                row.bill_rounds
            );
        }
    });
}

/// E11 fusion acceptance (ISSUE 8): 8 concurrent power-method tenants,
/// unfused-overlapped vs fused. Bills == solo, Σ == aggregate, and the
/// every-round fusion-engagement counters are `ensure!`d inside the
/// driver unconditionally; the `<= 0.6x` wall-clock gate arms under
/// DSPCA_STRESS=1.
#[test]
fn fused_rounds_beat_unfused_overlap_at_eight_tenants() {
    use dspca::experiments::serve::{run_fusion, FusionSweepConfig};
    let cfg = FusionSweepConfig {
        assert_speedup: if gated() { Some(0.6) } else { None },
        ..Default::default()
    };
    let table = run_fusion(&cfg).unwrap();
    println!("fusion gate:\n{}", table.render());
}
