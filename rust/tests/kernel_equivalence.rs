//! Kernel-equivalence suite (ISSUE 6): the threaded / sparse / reduced-
//! precision shard kernels must agree with the exact scalar kernels, and
//! the communication bill must never depend on the compute-thread budget.
//!
//! Every property here uses the explicit `*_threads` kernel variants so
//! `cargo test` stays order-independent — only the bill-invariance test
//! touches the process-global budget, and it restores the default through
//! a drop guard even on panic.

use dspca::cluster::{Cluster, CommStats};
use dspca::coordinator::{Algorithm, DistributedPower, ShiftInvert};
use dspca::data::{CovModel, Shard, SparseDiag};
use dspca::linalg::{set_compute_threads, Matrix};
use dspca::propcheck::{run as propcheck, Config, Gen};

/// Random dense shard drawn from the property generator.
fn gen_shard(g: &mut Gen, n: usize, d: usize) -> Shard {
    let data = g.gaussian_vec(n * d);
    Shard::new(n, d, data)
}

/// Dense shard plus the bit-equal CSR shard (~`density` fill, every row
/// guaranteed one entry so no row is empty by chance).
fn gen_csr_pair(g: &mut Gen, n: usize, d: usize, density: f64) -> (Shard, Shard) {
    let mut dense = vec![0.0; n * d];
    let (mut indptr, mut indices, mut values) = (vec![0usize], Vec::new(), Vec::new());
    for r in 0..n {
        for c in 0..d {
            if g.f64_in(0.0, 1.0) < density || c == r % d {
                let x = g.rng().next_gaussian();
                dense[r * d + c] = x;
                indices.push(c as u32);
                values.push(x);
            }
        }
        indptr.push(values.len());
    }
    (Shard::new(n, d, dense), Shard::from_csr(n, d, indptr, indices, values))
}

#[test]
fn prop_threaded_cov_matvec_matches_scalar() {
    propcheck(Config::default().cases(32).seed(0x6e51), "threaded matvec == scalar", |g| {
        let n = g.usize_in(1, 90);
        let d = g.usize_in(2, 24);
        let shard = gen_shard(g, n, d);
        let v = g.gaussian_vec(d);
        let mut scratch = Vec::new();
        let mut want = vec![0.0; d];
        shard.cov_matvec_into_threads(&v, &mut scratch, &mut want, 1);
        for t in [2usize, 8] {
            let mut got = vec![0.0; d];
            shard.cov_matvec_into_threads(&v, &mut scratch, &mut got, t);
            for i in 0..d {
                let tol = 1e-12 * (1.0 + want[i].abs());
                assert!(
                    (got[i] - want[i]).abs() <= tol,
                    "n={n} d={d} t={t} i={i}: {} vs {}",
                    got[i],
                    want[i]
                );
            }
        }
    });
}

#[test]
fn prop_threaded_cov_matmat_matches_scalar() {
    propcheck(Config::default().cases(32).seed(0x6e52), "threaded matmat == scalar", |g| {
        let n = g.usize_in(1, 70);
        let d = g.usize_in(2, 20);
        let k = g.usize_in(1, 6);
        let shard = gen_shard(g, n, d);
        let v = Matrix::from_vec(d, k, g.gaussian_vec(d * k));
        let mut scratch = Vec::new();
        let mut want = Matrix::zeros(d, k);
        shard.cov_matmat_into_threads(&v, &mut scratch, &mut want, 1);
        for t in [2usize, 8] {
            let mut got = Matrix::zeros(d, k);
            shard.cov_matmat_into_threads(&v, &mut scratch, &mut got, t);
            let err = got.sub(&want).max_abs();
            assert!(err <= 1e-12 * (1.0 + want.max_abs()), "n={n} d={d} k={k} t={t}: {err:.3e}");
        }
    });
}

#[test]
fn prop_csr_kernels_match_dense_across_thread_counts() {
    propcheck(Config::default().cases(24).seed(0x6e53), "csr == dense", |g| {
        let n = g.usize_in(2, 50);
        let d = g.usize_in(2, 16);
        let k = g.usize_in(1, 4);
        let density = g.f64_in(0.05, 0.9);
        let (dense, csr) = gen_csr_pair(g, n, d, density);
        let v = g.gaussian_vec(d);
        let block = Matrix::from_vec(d, k, g.gaussian_vec(d * k));
        let mut scratch = Vec::new();
        let mut want_v = vec![0.0; d];
        dense.cov_matvec_into_threads(&v, &mut scratch, &mut want_v, 1);
        let mut want_m = Matrix::zeros(d, k);
        dense.cov_matmat_into_threads(&block, &mut scratch, &mut want_m, 1);
        for t in [1usize, 2, 8] {
            let mut got_v = vec![0.0; d];
            csr.cov_matvec_into_threads(&v, &mut scratch, &mut got_v, t);
            for i in 0..d {
                let tol = 1e-12 * (1.0 + want_v[i].abs());
                assert!((got_v[i] - want_v[i]).abs() <= tol, "matvec t={t} i={i}");
            }
            let mut got_m = Matrix::zeros(d, k);
            csr.cov_matmat_into_threads(&block, &mut scratch, &mut got_m, t);
            let err = got_m.sub(&want_m).max_abs();
            assert!(err <= 1e-12 * (1.0 + want_m.max_abs()), "matmat t={t}: {err:.3e}");
        }
        // the shared structural facts too
        assert_eq!(csr.n(), dense.n());
        assert_eq!(csr.d(), dense.d());
        let g_err = csr.empirical_covariance().sub(dense.empirical_covariance()).max_abs();
        assert!(g_err <= 1e-12, "gram: {g_err:.3e}");
    });
}

#[test]
fn prop_f32_fast_path_within_documented_bound() {
    propcheck(Config::default().cases(24).seed(0x6e54), "f32 error bound", |g| {
        let n = g.usize_in(4, 60);
        let d = g.usize_in(2, 12);
        let k = g.usize_in(1, 4);
        let shard = gen_shard(g, n, d);
        let v = Matrix::from_vec(d, k, g.gaussian_vec(d * k));
        let exact = shard.cov_matmat(&v);
        let fast = shard.cov_matmat_f32(&v);
        // bound: gamma * (|A|^T (|A| |V|))_{ij} / n with
        // gamma = (2(n + d) + 8) * 2^-24 — shard.rs module docs
        let abs_shard =
            Shard::new(n, d, shard.matrix().data().iter().map(|x| x.abs()).collect());
        let abs_v = Matrix::from_vec(d, k, v.data().iter().map(|x| x.abs()).collect());
        let bound = abs_shard.cov_matmat(&abs_v);
        let gamma = (2.0 * (n as f64 + d as f64) + 8.0) * 2f64.powi(-24);
        for i in 0..d {
            for c in 0..k {
                let err = (fast.get(i, c) - exact.get(i, c)).abs();
                assert!(
                    err <= gamma * bound.get(i, c) + 1e-12,
                    "n={n} d={d} k={k}: f32 error {err:.3e} exceeds bound at ({i},{c})"
                );
            }
        }
    });
}

#[test]
fn prop_matmul_threads_bit_identical() {
    // Owner-computes GEMM: every output row is written by exactly one
    // thread in the scalar loop order, so the result is bit-identical —
    // not merely close — at any thread count.
    propcheck(Config::default().cases(16).seed(0x6e55), "gemm bit-identical", |g| {
        // big enough to clear the kernel's small-product cutoff so the
        // panels genuinely run on separate threads
        let m = g.usize_in(40, 56);
        let k = g.usize_in(32, 48);
        let n = g.usize_in(32, 48);
        let a = Matrix::from_vec(m, k, g.gaussian_vec(m * k));
        let b = Matrix::from_vec(k, n, g.gaussian_vec(k * n));
        let want = a.matmul_threads(&b, 1);
        for t in [2usize, 3, 8] {
            let got = a.matmul_threads(&b, t);
            assert!(got.data() == want.data(), "gemm t={t} not bit-identical");
        }
    });
}

/// Restores the default single-thread budget even if the test panics, so
/// no other test in this binary can observe a stray global.
struct ThreadBudgetGuard;

impl Drop for ThreadBudgetGuard {
    fn drop(&mut self) {
        set_compute_threads(1);
    }
}

#[test]
fn bills_are_invariant_across_thread_counts() {
    // The tentpole's contract: threads change wall clock, never the bill.
    // Run the same convergence-dependent algorithms under thread budgets
    // 1 and 4 and require the CommStats to be *exactly* equal — rounds,
    // messages, and bytes are all convergence-driven, so this catches any
    // numerical drift large enough to flip an iteration count.
    let _guard = ThreadBudgetGuard;
    let dense_dist = CovModel::paper_fig1(12, 0x1111).gaussian();
    let sparse_dist = SparseDiag::paper_fig1(16, 0.3);
    let run_all = |threads: usize| -> Vec<(String, CommStats)> {
        set_compute_threads(threads);
        let mut bills = Vec::new();
        let dense = Cluster::generate(&dense_dist, 3, 60, 5).unwrap();
        for alg in [
            &DistributedPower::default() as &dyn Algorithm,
            &ShiftInvert::default(),
        ] {
            let session = dense.session();
            let est = alg.run(&session).unwrap();
            assert!(est.w.iter().all(|x| x.is_finite()));
            bills.push((format!("dense/{}", alg.name()), session.close()));
        }
        let sparse = Cluster::generate(&sparse_dist, 3, 80, 6).unwrap();
        let session = sparse.session();
        let est = DistributedPower::default().run(&session).unwrap();
        assert!(est.w.iter().all(|x| x.is_finite()));
        bills.push(("sparse/power".to_string(), session.close()));
        bills
    };
    let at_1 = run_all(1);
    let at_4 = run_all(4);
    set_compute_threads(1);
    assert_eq!(at_1.len(), at_4.len());
    for ((name1, bill1), (name4, bill4)) in at_1.iter().zip(at_4.iter()) {
        assert_eq!(name1, name4);
        assert_eq!(bill1, bill4, "{name1}: bill differs between 1 and 4 threads");
    }
}
