//! Empirically verify the paper's lower bounds:
//!
//! - **Theorem 3**: naive averaging on the appendix construction stays at
//!   `Theta(1/n)` — the fitted log-log slope in `n` is ~-1 and does not
//!   improve with the number of machines.
//! - **Theorem 5**: sign-fixed averaging on the asymmetric-`xi`
//!   construction carries a `1/(delta^4 n^2)` bias — with many machines
//!   the slope bends toward -2.

use dspca::experiments::lower_bounds::{run_thm3, run_thm5, LowerBoundConfig};

fn main() -> anyhow::Result<()> {
    let cfg = LowerBoundConfig::default();
    println!(
        "=== lower bounds: n in {:?}, m in {:?}, runs={} ===",
        cfg.n_list, cfg.m_list, cfg.runs
    );

    let (t3, slopes) = run_thm3(&cfg)?;
    println!("\nTheorem 3 (naive averaging), fitted error ~ n^slope per m:");
    for (m, s) in cfg.m_list.iter().zip(&slopes) {
        println!("  m={m:>3}: slope {s:+.2}   (lower bound Omega(1/n); measured: flat, m-independent)");
    }
    t3.write("results/thm3_naive.csv")?;

    let (t5, slope) = run_thm5(&cfg)?;
    println!("\nTheorem 5 (sign-fixing bias, m={}):", cfg.m_list.last().unwrap());
    println!("  slope {slope:+.2}   (theory: -> -2 once the n^-2 bias dominates)");
    t5.write("results/thm5_signfix.csv")?;

    println!("\nwrote results/thm3_naive.csv, results/thm5_signfix.csv");
    Ok(())
}
