//! The multi-process deployment shape, demonstrated in one process:
//! three TCP workers on ephemeral loopback ports (in-thread stand-ins
//! for three `dspca worker --listen <addr>` terminals), a leader
//! cluster connected over real sockets, and the transport contract
//! checked live — the TCP run's estimate and `CommStats` bill are
//! identical to the in-proc run at the same seed.
//!
//! ```sh
//! cargo run --release --example tcp_loopback
//! ```

use dspca::prelude::*;
use dspca::transport::LoopbackWorkers;

fn main() -> anyhow::Result<()> {
    let (d, m, n, seed) = (48usize, 3usize, 300usize, 42u64);
    let dist = CovModel::paper_fig1(d, 7).gaussian();

    // in-proc reference run
    let inproc = Cluster::generate(&dist, m, n, seed)?;
    let reference = DistributedPower::default().run(&inproc.session())?;
    drop(inproc);
    println!(
        "inproc: err={:.3e} rounds={} bytes={}",
        reference.error(dist.v1()),
        reference.comm.rounds,
        reference.comm.bytes
    );

    // three TCP workers; each serves one leader connection then exits
    let workers = LoopbackWorkers::spawn(m, 1)?;
    println!("tcp workers listening on {:?}", workers.addrs());
    let tcp = Cluster::generate_on(&dist, m, n, seed, OracleSpec::Native, &workers.spec())?;
    let est = DistributedPower::default().run(&tcp.session())?;
    println!(
        "tcp:    err={:.3e} rounds={} bytes={}  (transport = {})",
        est.error(dist.v1()),
        est.comm.rounds,
        est.comm.bytes,
        tcp.transport_name()
    );

    assert_eq!(est.comm, reference.comm, "bills must be backend-invariant");
    assert_eq!(est.w, reference.w, "estimates must be backend-invariant");
    drop(tcp);
    workers.join()?;
    println!("OK: the TCP loopback run billed and estimated identically to in-proc");
    Ok(())
}
