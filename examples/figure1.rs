//! Reproduce **Figure 1** (both panes): estimation error vs per-machine
//! sample size for the five §5 estimators, gaussian and scaled-uniform
//! data.
//!
//! Paper settings: d = 300, m = 25, 400 runs. Default here uses
//! `DSPCA_RUNS` (default 40) to stay interactive; run
//! `DSPCA_RUNS=400 cargo run --release --example figure1` for the full
//! reproduction. CSVs land in `results/`.

use dspca::cluster::OracleSpec;
use dspca::experiments::figure1::{run, Fig1Config, Fig1Dist};

fn main() -> anyhow::Result<()> {
    for dist in [Fig1Dist::Gaussian, Fig1Dist::ScaledUniform] {
        let cfg = Fig1Config { dist, oracle: OracleSpec::Native, ..Default::default() };
        println!(
            "=== Figure 1 ({dist:?}): d={} m={} runs={} ===",
            cfg.d, cfg.m, cfg.runs
        );
        let table = run(&cfg)?;
        let path = format!("results/figure1_{dist:?}.csv").to_lowercase();
        table.write(&path)?;
        println!("wrote {path}\n");
    }
    Ok(())
}
