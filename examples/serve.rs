//! Multi-tenant serving: two concurrent tenants — one lossless power
//! method, one bf16-quantized power method — answering queries against
//! **one** shared cluster, with fully independent communication bills,
//! followed by a batch through the `serve` scheduler.
//!
//! ```sh
//! cargo run --release --example serve
//! ```

use dspca::prelude::*;
use dspca::serve::{serve, Job};

fn main() -> anyhow::Result<()> {
    let (d, m, n) = (60, 8, 400);
    let dist = CovModel::paper_fig1(d, 7).gaussian();
    println!("multi-tenant cluster: m={m} machines x n={n} samples, d={d}\n");
    let cluster = Cluster::generate(&dist, m, n, 42)?;

    // --- two tenants, by hand: one thread each, one session each -----
    let power = DistributedPower::default();
    let quant = QuantizedPower::new(WirePrecision::Bf16);
    let agg0 = cluster.aggregate_stats();
    let (lossless, lossy) = std::thread::scope(|s| {
        let h1 = s.spawn(|| power.run(&cluster.session()).unwrap());
        let h2 = s.spawn(|| quant.run(&cluster.session()).unwrap());
        (h1.join().unwrap(), h2.join().unwrap())
    });
    println!("{:<18} {:>10} {:>8} {:>12} {:>12}", "tenant", "error", "rounds", "bytes", "B/round");
    println!("{}", "-".repeat(64));
    for (name, est) in [("lossless f64", &lossless), ("quantized bf16", &lossy)] {
        println!(
            "{:<18} {:>10.3e} {:>8} {:>12} {:>12.0}",
            name,
            est.error(dist.v1()),
            est.comm.rounds,
            est.comm.bytes,
            est.comm.bytes as f64 / est.comm.rounds.max(1) as f64
        );
    }
    let mut sum = lossless.comm.clone();
    sum.merge(&lossy.comm);
    let window = cluster.aggregate_stats().delta_since(&agg0);
    assert_eq!(sum, window, "per-tenant bills must sum to the cluster aggregate");
    println!(
        "\nbills are independent (bf16 tenant ships 2-byte frames, f64 tenant 8-byte)\n\
         and sum exactly to the cluster aggregate: {window}\n"
    );

    // --- the same thing at batch scale, through the scheduler --------
    let jobs = vec![
        Job::new("power", Box::new(DistributedPower::default())),
        Job::new("bf16-power", Box::new(QuantizedPower::new(WirePrecision::Bf16))),
        Job::new("sign-fixed", Box::new(SignFixedAverage)),
        Job::new("lanczos", Box::new(DistributedLanczos::default())),
        Job::new("projection", Box::new(ProjectionAverage)),
        Job::new("shift-invert", Box::new(ShiftInvert::default())),
    ];
    let report = serve(&cluster, jobs, 3)?;
    assert!(report.accounting_exact, "exclusive batch: per-job bills sum to the aggregate");
    println!("serve: {} jobs over 3 tenants in {:?} ({:.1} jobs/s)", report.jobs.len(), report.wall, report.throughput);
    println!("{:<16} {:>22} {:>8} {:>12} {:>12}", "job", "algorithm", "rounds", "bytes", "latency");
    println!("{}", "-".repeat(74));
    for j in &report.jobs {
        println!(
            "{:<16} {:>22} {:>8} {:>12} {:>12?}",
            j.name, j.alg, j.comm.rounds, j.comm.bytes, j.latency
        );
    }
    println!("\naggregate over the batch: {}", report.aggregate);
    Ok(())
}
