//! **End-to-end driver**: the full three-layer stack on a real workload.
//!
//! Every per-machine numerical operation (covariance matvecs, local
//! eigensolves, Gram builds, Oja passes) executes through the AOT
//! pipeline: Pallas kernels -> JAX model -> HLO text -> PJRT CPU client
//! inside each Rust worker thread. Python is not running.
//!
//! Prints, per algorithm: estimation error vs the population `v_1`,
//! communication rounds, wallclock, and the per-round latency /
//! throughput of the PJRT path vs the native Rust path.
//!
//! ```sh
//! make artifacts && cargo run --release --example e2e_pjrt
//! ```

use std::time::Instant;

use dspca::prelude::*;

fn main() -> anyhow::Result<()> {
    let artifacts = dspca::runtime::default_artifact_dir();
    if !artifacts.join("manifest.json").exists() {
        anyhow::bail!("artifacts not found at {} — run `make artifacts` first", artifacts.display());
    }
    // shapes must match an AOT artifact (see python/compile/aot.py)
    let (m, n, d) = (4, 400, 64);
    let dist = CovModel::paper_fig1(d, 3).gaussian();
    println!("e2e: m={m} n={n} d={d}, artifacts={}", artifacts.display());
    println!("Lemma-1 eps_ERM bound (p=1/4): {:.3e}\n", dist.eps_erm(m, n, 0.25));

    let algorithms: Vec<Box<dyn Algorithm>> = vec![
        Box::new(CentralizedErm),
        Box::new(NaiveAverage),
        Box::new(SignFixedAverage),
        Box::new(ProjectionAverage),
        Box::new(DistributedLanczos::default()),
        Box::new(HotPotatoOja::default()),
        Box::new(ShiftInvert::default()),
    ];

    for (tag, spec) in [
        ("pjrt", OracleSpec::Pjrt { artifact_dir: artifacts.to_string_lossy().into_owned() }),
        ("native", OracleSpec::Native),
    ] {
        println!("--- oracle: {tag} ---");
        let cluster = Cluster::generate_with(&dist, m, n, 42, spec)?;
        println!(
            "{:<22} {:>11} {:>7} {:>9} {:>12} {:>14}",
            "method", "error", "rounds", "matvecs", "wall", "per-round"
        );
        for alg in &algorithms {
            let est = alg.run(&cluster.session())?;
            let per_round = if est.comm.rounds > 0 {
                est.wall / est.comm.rounds as u32
            } else {
                std::time::Duration::ZERO
            };
            println!(
                "{:<22} {:>11.3e} {:>7} {:>9} {:>12?} {:>14?}",
                alg.name(),
                est.error(dist.v1()),
                est.comm.rounds,
                est.comm.matvec_products,
                est.wall,
                per_round
            );
        }
        // raw matvec round latency / throughput
        let v = vec![1.0 / (d as f64).sqrt(); d];
        let session = cluster.session();
        let _ = session.dist_matvec(&v)?; // warm (compilation, buffers)
        let reps = 200;
        let t0 = Instant::now();
        for _ in 0..reps {
            std::hint::black_box(session.dist_matvec(&v)?);
        }
        let per = t0.elapsed() / reps;
        println!(
            "matvec round latency: {per:?}  ({:.0} rounds/s, {m} workers x {n}x{d} shard)\n",
            1.0 / per.as_secs_f64()
        );
    }
    println!("both oracles agree numerically (f64 artifacts); see runtime tests for bit-level checks");
    Ok(())
}
