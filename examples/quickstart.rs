//! Quickstart: generate a distributed dataset, run every estimator once,
//! and print the error / communication trade-off the paper is about.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use dspca::prelude::*;

fn main() -> anyhow::Result<()> {
    // The paper's §5 covariance model: d = 300, delta = 0.2.
    let d = 300;
    let (m, n) = (25, 400);
    let dist = CovModel::paper_fig1(d, 7).gaussian();
    println!("distributed PCA: m={m} machines x n={n} samples, d={d}, delta={}", dist.eigengap());
    println!("Lemma-1 eps_ERM bound (p=1/4): {:.3e}\n", dist.eps_erm(m, n, 0.25));

    let cluster = Cluster::generate(&dist, m, n, 42)?;

    let algorithms: Vec<Box<dyn Algorithm>> = vec![
        Box::new(CentralizedErm),
        Box::new(NaiveAverage),
        Box::new(SignFixedAverage),
        Box::new(ProjectionAverage),
        Box::new(DistributedPower::default()),
        Box::new(DistributedLanczos::default()),
        Box::new(HotPotatoOja::default()),
        Box::new(ShiftInvert::default()),
    ];

    println!(
        "{:<22} {:>12} {:>8} {:>10} {:>12}",
        "method", "error", "rounds", "matvecs", "wall"
    );
    println!("{}", "-".repeat(70));
    for alg in &algorithms {
        // one tenant session per query: each run carries its own bill,
        // and any number of sessions may run concurrently on the shared
        // cluster (see examples/serve.rs)
        let est = alg.run(&cluster.session())?;
        println!(
            "{:<22} {:>12.3e} {:>8} {:>10} {:>12?}",
            alg.name(),
            est.error(dist.v1()),
            est.comm.rounds,
            est.comm.matvec_products,
            est.wall
        );
    }
    println!("\n(naive averaging stalls near the single-machine error — Theorem 3;");
    println!(" sign-fixing rescues it with the same single round — Theorem 4.)");
    Ok(())
}
