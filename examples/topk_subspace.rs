//! Top-`k` subspace estimation — the general problem of the paper's
//! Eq. (1)/(2) (the paper's algorithms specialize to `k = 1`; its
//! appendix Theorem 7 supplies the general-`k` Davis-Kahan metric used
//! here).
//!
//! Compares: centralized top-k, distributed block power (orthogonal
//! iteration), one-round projector averaging, and deflated
//! Shift-and-Invert. Error: `k - ||W^T V||_F^2` against the population
//! top-k basis.

use dspca::cluster::Cluster;
use dspca::coordinator::subspace::{
    top_k_basis, CentralizedSubspace, DeflatedShiftInvert, DistributedOrthoIteration,
    SubspaceProjectionAverage,
};
use dspca::data::CovModel;

fn main() -> anyhow::Result<()> {
    let (d, m, n, k) = (60, 8, 500, 4);
    let model = CovModel::paper_fig1(d, 17);
    let dist = model.clone().gaussian();
    let v = top_k_basis(&model, k);
    println!("top-{k} subspace: m={m} x n={n}, d={d} (population spectrum 1, .8, .72, …)\n");
    let cluster = Cluster::generate(&dist, m, n, 4242)?;

    println!("{:<28} {:>12} {:>8} {:>10}", "method", "subspace err", "rounds", "matvecs");
    println!("{}", "-".repeat(62));
    let cen = CentralizedSubspace { k }.run_mat(&cluster)?;
    println!("{:<28} {:>12.3e} {:>8} {:>10}", "centralized top-k", cen.error(&v), cen.comm.rounds, cen.comm.matvec_products);
    let blk = DistributedOrthoIteration::new(k).run_mat(&cluster)?;
    println!("{:<28} {:>12.3e} {:>8} {:>10}", "block power (ortho iter)", blk.error(&v), blk.comm.rounds, blk.comm.matvec_products);
    let proj = SubspaceProjectionAverage { k }.run_mat(&cluster)?;
    println!("{:<28} {:>12.3e} {:>8} {:>10}", "projector averaging (1 rd)", proj.error(&v), proj.comm.rounds, proj.comm.matvec_products);
    let defl = DeflatedShiftInvert::new(k).run_mat(&cluster)?;
    println!("{:<28} {:>12.3e} {:>8} {:>10}", "deflated shift-invert", defl.error(&v), defl.comm.rounds, defl.comm.matvec_products);
    println!("\n(block power + deflated S&I match the centralized subspace;\n projector averaging is the k>1 analog of the paper's §5 heuristic)");
    Ok(())
}
