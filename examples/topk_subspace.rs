//! Top-`k` subspace estimation — the general problem of the paper's
//! Eq. (1)/(2) (the paper's algorithms specialize to `k = 1`; its
//! appendix Theorem 7 supplies the general-`k` Davis-Kahan metric used
//! here).
//!
//! The whole iterative family runs on the cluster's **block protocol**:
//! one `dist_matmat` round per iteration moves the entire `d x k` basis
//! as a single message per worker, so the round and message columns
//! below stay flat in `k` where a column-wise loop would scale linearly.
//!
//! Compares: centralized top-k, distributed block power (orthogonal
//! iteration), block Lanczos, one-round projector averaging, and
//! deflated Shift-and-Invert with batched trailing components. Error:
//! `k - ||W^T V||_F^2` against the population top-k basis.

use dspca::cluster::Cluster;
use dspca::coordinator::subspace::{
    top_k_basis, CentralizedSubspace, DeflatedShiftInvert, DistributedOrthoIteration,
    SubspaceEstimate, SubspaceProjectionAverage,
};
use dspca::coordinator::BlockLanczos;
use dspca::data::CovModel;

fn report(name: &str, v: &dspca::linalg::Matrix, est: &SubspaceEstimate) {
    println!(
        "{:<28} {:>12.3e} {:>8} {:>10} {:>10}",
        name,
        est.error(v),
        est.comm.rounds,
        est.comm.matvec_products,
        est.comm.requests_sent
    );
}

fn main() -> anyhow::Result<()> {
    let (d, m, n, k) = (60, 8, 500, 4);
    let model = CovModel::paper_fig1(d, 17);
    let dist = model.clone().gaussian();
    let v = top_k_basis(&model, k);
    println!("top-{k} subspace: m={m} x n={n}, d={d} (population spectrum 1, .8, .72, …)\n");
    let cluster = Cluster::generate(&dist, m, n, 4242)?;

    println!(
        "{:<28} {:>12} {:>8} {:>10} {:>10}",
        "method", "subspace err", "rounds", "matvecs", "messages"
    );
    println!("{}", "-".repeat(74));
    let cen = CentralizedSubspace { k }.run_mat(&cluster.session())?;
    report("centralized top-k", &v, &cen);
    let blk = DistributedOrthoIteration::new(k).run_mat(&cluster.session())?;
    report("block power (1 rd/iter)", &v, &blk);
    let lan = BlockLanczos::new(k).run_mat(&cluster.session())?;
    report("block Lanczos (1 rd/block)", &v, &lan);
    let proj = SubspaceProjectionAverage { k }.run_mat(&cluster.session())?;
    report("projector averaging (1 rd)", &v, &proj);
    let defl = DeflatedShiftInvert::new(k).run_mat(&cluster.session())?;
    report("deflated S&I (batched)", &v, &defl);
    println!(
        "\n(block power, block Lanczos and deflated S&I match the centralized\n\
         subspace; each of their iterations is ONE round / ONE message per\n\
         worker carrying k vectors — the column-wise loop paid k of each.\n\
         projector averaging is the k>1 analog of the paper's §5 heuristic)"
    );
    Ok(())
}
