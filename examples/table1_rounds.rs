//! Reproduce **Table 1**: per-method estimation error and communication
//! rounds on one fixed workload (empirical counterpart of the paper's
//! analytic table).

use dspca::data::Distribution;
use dspca::experiments::table1::{render_rows, run, Table1Config};

fn main() -> anyhow::Result<()> {
    let cfg = Table1Config::default();
    println!("=== Table 1: d={} m={} n={} runs={} ===", cfg.d, cfg.m, cfg.n, cfg.runs);
    let (rows, table) = run(&cfg)?;
    let dist = dspca::data::CovModel::paper_fig1(cfg.d, cfg.seed ^ 0x7a).gaussian();
    println!("{}", render_rows(&rows, dist.eps_erm(cfg.m, cfg.n, 0.25)));
    table.write("results/table1.csv")?;
    println!("wrote results/table1.csv");
    Ok(())
}
