"""Pure-jnp oracles for the Pallas kernels.

Every kernel in this package has a reference implementation here; the
pytest suite sweeps shapes and dtypes (hypothesis) asserting allclose
between kernel and oracle. The oracles are also what the L2 model would
be without Pallas — useful for HLO-level A/B comparisons.
"""

import jax.numpy as jnp


def cov_matvec(a, v):
    """``Xhat v = A^T (A v) / n`` for a shard ``A: (n, d)``."""
    n = a.shape[0]
    return (a.T @ (a @ v)) / n


def gram(a):
    """Empirical covariance ``Xhat = A^T A / n``."""
    n = a.shape[0]
    return (a.T @ a) / n


def power_iterations(g, v0, iters):
    """`iters` normalized power iterations with the matrix ``g``."""
    w = v0 / jnp.linalg.norm(v0)
    for _ in range(iters):
        w = g @ w
        w = w / jnp.maximum(jnp.linalg.norm(w), 1e-300)
    return w


def oja_pass(a, w, eta0, t0, t_start):
    """Sequential Oja pass over the rows of ``a`` (python loop oracle)."""
    w = w / jnp.linalg.norm(w)
    for i in range(a.shape[0]):
        eta = eta0 / (t0 + t_start + i)
        x = a[i]
        w = w + eta * x * (x @ w)
        w = w / jnp.linalg.norm(w)
    return w
