"""Pallas kernels (L1) + pure-jnp oracles.

All kernels are lowered with ``interpret=True`` (CPU PJRT cannot execute
Mosaic custom calls); see DESIGN.md §Hardware-Adaptation for the TPU
mapping they encode.
"""

from . import ref  # noqa: F401
from .cov_matvec import cov_matvec  # noqa: F401
from .gram import gram  # noqa: F401
