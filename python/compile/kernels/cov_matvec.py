"""L1 Pallas kernel: distributed-PCA covariance matvec ``A^T (A v) / n``.

This is the per-machine hot spot of every iterative algorithm in the
paper (power method, Lanczos, and each CG step of the Shift-and-Invert
solver): the worker receives ``v`` from the leader and must return
``Xhat_i v`` without materializing the d*d covariance.

TPU mapping (DESIGN.md §Hardware-Adaptation): the shard ``A`` is streamed
through VMEM in ``(BLK_N, d)`` row panels (grid over row blocks), while
``v`` and the ``d``-vector accumulator stay VMEM-resident. Both products
per panel (``A_blk @ v`` and ``(A_blk v) @ A_blk``) are MXU-shaped
matmuls; cross-panel accumulation uses the revisiting-output pattern
(the output block index is constant along the grid).

CPU note: lowered with ``interpret=True`` — the CPU PJRT plugin cannot
execute Mosaic custom calls; correctness is validated against
``ref.cov_matvec`` and the AOT artifact runs on the Rust PJRT client.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Row-panel height. 128 keeps the (BLK_N x d) f32 panel + v + accumulator
# comfortably inside a 16 MB VMEM budget up to d ~ 8k; for the paper's
# d = 300 the panel is ~150 KB.
DEFAULT_BLOCK_N = 128


def _kernel(a_ref, v_ref, o_ref):
    """One grid step: accumulate ``A_blk^T (A_blk v)`` into ``o_ref``."""
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...]  # (blk_n, d) panel, VMEM
    v = v_ref[...]  # (d,) resident
    av = a @ v  # (blk_n,)  — MXU matvec
    o_ref[...] += av @ a  # (d,)     — MXU matvec (A^T partial)


def cov_matvec(a, v, *, block_n: int = DEFAULT_BLOCK_N, interpret: bool = True):
    """``A^T (A v) / n`` via the tiled Pallas kernel.

    Rows are zero-padded up to a multiple of ``block_n``; zero rows
    contribute nothing to ``A^T A v`` so the result is exact.
    """
    n, d = a.shape
    blk = min(block_n, n)
    pad = (-n) % blk
    if pad:
        a = jnp.concatenate([a, jnp.zeros((pad, d), a.dtype)], axis=0)
    grid = (a.shape[0] // blk,)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((blk, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((d,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((d,), a.dtype),
        interpret=interpret,
    )(a, v)
    return out / n


@functools.cache
def vmem_estimate_bytes(n: int, d: int, itemsize: int = 4, block_n: int = DEFAULT_BLOCK_N) -> int:
    """Static VMEM footprint estimate for DESIGN.md/EXPERIMENTS.md §Perf:
    one ``(blk, d)`` panel + ``v`` + accumulator + the ``(blk,)`` temp."""
    blk = min(block_n, n)
    return itemsize * (blk * d + d + d + blk)
