"""L1 Pallas kernel: empirical covariance ``A^T A / n`` (tiled SYRK).

Feeds the one-shot estimators (each machine's local eigensolve needs its
Gram matrix) and the centralized baseline. Same streaming layout as
``cov_matvec``: row panels through VMEM, ``(d, d)`` accumulator resident,
one MXU ``A_blk^T @ A_blk`` per panel with revisiting-output
accumulation.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_N = 128


def _kernel(a_ref, o_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...]  # (blk_n, d)
    o_ref[...] += a.T @ a  # (d, d) MXU panel update


def gram(a, *, block_n: int = DEFAULT_BLOCK_N, interpret: bool = True):
    """``A^T A / n`` via the tiled Pallas kernel (zero-pad exactness as in
    ``cov_matvec``)."""
    n, d = a.shape
    blk = min(block_n, n)
    pad = (-n) % blk
    if pad:
        a = jnp.concatenate([a, jnp.zeros((pad, d), a.dtype)], axis=0)
    grid = (a.shape[0] // blk,)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((blk, d), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((d, d), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((d, d), a.dtype),
        interpret=interpret,
    )(a)
    return out / n


def vmem_estimate_bytes(n: int, d: int, itemsize: int = 4, block_n: int = DEFAULT_BLOCK_N) -> int:
    """Panel + (d, d) accumulator footprint."""
    blk = min(block_n, n)
    return itemsize * (blk * d + d * d)
