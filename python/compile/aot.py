"""AOT pipeline: lower the L2 entry points to XLA HLO **text** and write
``artifacts/<name>_<n>x<d>.hlo.txt`` plus ``artifacts/manifest.json``.

HLO *text* (not a serialized ``HloModuleProto``) is the interchange
format: jax >= 0.5 emits protos with 64-bit instruction ids that the
``xla`` crate's xla_extension 0.5.1 rejects; the text parser reassigns
ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage::

    python -m compile.aot [--out-dir ../artifacts] [--shapes 400x64,200x32]

Shapes can also be set via ``DSPCA_AOT_SHAPES``. Idempotent: `make
artifacts` skips the build when inputs are unchanged.
"""

import argparse
import json
import os

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

#: default (n, d) shard shapes — what examples/e2e_pjrt.rs and
#: benches/bench_runtime.rs request.
DEFAULT_SHAPES = [(400, 64), (200, 32)]

F64 = jnp.float64


def to_hlo_text(fn, example_args) -> str:
    """jit -> lower -> StableHLO -> XlaComputation -> HLO text."""
    lowered = jax.jit(fn).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def entry_points(n: int, d: int):
    """The lowering plan for one shard shape: (name, fn, arg specs)."""
    a = jax.ShapeDtypeStruct((n, d), F64)
    vec = jax.ShapeDtypeStruct((d,), F64)
    scalar = jax.ShapeDtypeStruct((), F64)
    return [
        ("cov_matvec", model.cov_matvec, (a, vec), [[n, d], [d]], [[d]]),
        ("gram", model.gram, (a,), [[n, d]], [[d, d]]),
        ("local_top_eigvec", model.local_top_eigvec, (a, vec), [[n, d], [d]], [[d]]),
        (
            "oja_pass",
            model.oja_pass,
            (a, vec, scalar, scalar, scalar),
            [[n, d], [d], [], [], []],
            [[d]],
        ),
    ]


def parse_shapes(text: str):
    shapes = []
    for part in text.split(","):
        part = part.strip()
        if not part:
            continue
        n, d = part.lower().split("x")
        shapes.append((int(n), int(d)))
    return shapes


def build(out_dir: str, shapes) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    entries = []
    for n, d in shapes:
        for name, fn, args, in_shapes, out_shapes in entry_points(n, d):
            fname = f"{name}_{n}x{d}.hlo.txt"
            text = to_hlo_text(fn, args)
            with open(os.path.join(out_dir, fname), "w") as f:
                f.write(text)
            entries.append(
                {
                    "name": name,
                    "n": n,
                    "d": d,
                    "file": fname,
                    "inputs": in_shapes,
                    "outputs": out_shapes,
                }
            )
            print(f"  wrote {fname} ({len(text)} chars)")
    manifest = {"version": 1, "dtype": "f64", "entries": entries}
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"manifest: {len(entries)} entries -> {out_dir}/manifest.json")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=os.path.join("..", "artifacts"))
    ap.add_argument(
        "--shapes",
        default=os.environ.get("DSPCA_AOT_SHAPES", ""),
        help="comma-separated NxD shard shapes (default: 400x64,200x32)",
    )
    args = ap.parse_args()
    shapes = parse_shapes(args.shapes) if args.shapes else DEFAULT_SHAPES
    build(args.out_dir, shapes)


if __name__ == "__main__":
    main()
