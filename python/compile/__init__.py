"""Build-time compile package: L2 JAX model + L1 Pallas kernels + AOT.

Never imported at runtime — the Rust coordinator only consumes the HLO
artifacts emitted by ``python -m compile.aot``.
"""
