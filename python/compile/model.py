"""L2: the per-machine JAX compute graph, built on the L1 Pallas kernels.

These are the functions AOT-lowered to HLO text (``python -m
compile.aot``) and executed by the Rust workers through PJRT. Python is
never on the request path: each function is jitted/lowered once per
shard shape at build time.

Entry points
------------
- ``cov_matvec(a, v)``           — one covariance matvec (Algorithm 2 inner op)
- ``gram(a)``                    — local empirical covariance
- ``local_top_eigvec(a, v0)``    — the machine's ERM solution by chained
  power iterations on the (kernel-produced) Gram matrix; the
  ``lax.fori_loop`` keeps all iterations inside ONE executable so a local
  eigensolve costs a single PJRT dispatch.
- ``oja_pass(a, w, sched)``      — one hot-potato SGD pass over the shard.

Everything runs in f64 (``jax_enable_x64``) so the PJRT path is
bit-comparable with the Rust-native oracle (DESIGN.md §Numerics).
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
from jax import lax

from .kernels import cov_matvec as _pallas_cov_matvec
from .kernels import gram as _pallas_gram

#: power-iteration count baked into the local eigensolve executable.
#: Contraction per iteration is (lambda2/lambda1)^2; 300 iterations
#: resolve gaps down to ~1% of lambda_1 at f64 accuracy.
LOCAL_EIG_ITERS = 300


def cov_matvec(a, v):
    """``Xhat_i v`` for one shard (Pallas kernel, normalized)."""
    return _pallas_cov_matvec(a, v)


def gram(a):
    """``Xhat_i`` for one shard (Pallas kernel)."""
    return _pallas_gram(a)


def _sign_canonical(w):
    """Deterministic sign: component of largest magnitude made positive
    (matches the Rust ``SymEigen::leading`` convention)."""
    idx = jnp.argmax(jnp.abs(w))
    return w * jnp.sign(w[idx])


def local_top_eigvec(a, v0):
    """Local ERM: leading eigenvector of ``A^T A / n``.

    One Pallas Gram build + ``LOCAL_EIG_ITERS`` fused power iterations.
    Returns the unit eigenvector with canonical sign.
    """
    g = _pallas_gram(a)

    def body(_, w):
        w = g @ w
        return w / jnp.maximum(jnp.linalg.norm(w), 1e-300)

    w0 = v0 / jnp.maximum(jnp.linalg.norm(v0), 1e-300)
    w = lax.fori_loop(0, LOCAL_EIG_ITERS, body, w0)
    return _sign_canonical(w)


def oja_pass(a, w, eta0, t0, t_start):
    """One sequential Oja pass over the shard rows:
    ``w <- normalize(w + eta_t x_t (x_t^T w))``, ``eta_t = eta0/(t0+t)``.

    Sequential by construction (each step depends on the last), so the
    fori_loop lowers to a single HLO while-loop — one PJRT dispatch per
    machine visit, matching the paper's m-round accounting.
    """
    n = a.shape[0]

    def body(i, w):
        eta = eta0 / (t0 + t_start + i)
        x = lax.dynamic_slice_in_dim(a, i, 1, axis=0)[0]
        w = w + eta * x * (x @ w)
        return w / jnp.maximum(jnp.linalg.norm(w), 1e-300)

    w = w / jnp.maximum(jnp.linalg.norm(w), 1e-300)
    return lax.fori_loop(0, n, body, w)
