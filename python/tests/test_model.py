"""L2 correctness: model entry points vs numpy/oracle references."""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


def _shard(n, d, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal((n, d)))


def _aniso_shard(n, d, seed, scale0=3.0):
    """Shard with a dominant first coordinate (clear top eigenvector)."""
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((n, d))
    a[:, 0] *= scale0
    return jnp.asarray(a)


def test_cov_matvec_entry_matches_ref():
    a = _shard(40, 7, 0)
    v = jnp.asarray(np.random.default_rng(1).standard_normal(7))
    np.testing.assert_allclose(model.cov_matvec(a, v), ref.cov_matvec(a, v), rtol=1e-12)


def test_gram_entry_matches_ref():
    a = _shard(25, 5, 2)
    np.testing.assert_allclose(model.gram(a), ref.gram(a), rtol=1e-12)


def test_local_top_eigvec_matches_numpy_eigh():
    a = _aniso_shard(300, 6, 3)
    v0 = jnp.ones(6)
    w = np.asarray(model.local_top_eigvec(a, v0))
    g = np.asarray(ref.gram(a))
    evals, evecs = np.linalg.eigh(g)
    v1 = evecs[:, -1]
    align = abs(float(w @ v1))
    assert align > 1.0 - 1e-10, f"alignment {align}"
    # unit norm + canonical sign (largest-|component| positive)
    np.testing.assert_allclose(np.linalg.norm(w), 1.0, rtol=1e-12)
    assert w[np.argmax(np.abs(w))] > 0


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_local_top_eigvec_rayleigh_is_lambda1(seed):
    a = _aniso_shard(120, 5, seed)
    w = np.asarray(model.local_top_eigvec(a, jnp.ones(5)))
    g = np.asarray(ref.gram(a))
    rq = float(w @ g @ w)
    lam1 = np.linalg.eigvalsh(g)[-1]
    assert abs(rq - lam1) < 1e-8 * max(1.0, lam1)


def test_oja_pass_matches_python_oracle():
    a = _shard(30, 4, 7)
    w0 = jnp.asarray([1.0, 0.0, 0.0, 0.0])
    got = np.asarray(model.oja_pass(a, w0, 0.5, 10.0, 0.0))
    want = np.asarray(ref.oja_pass(a, w0, 0.5, 10.0, 0))
    np.testing.assert_allclose(got, want, rtol=1e-10, atol=1e-12)


def test_oja_pass_t_start_offset_matters():
    a = _shard(20, 3, 8)
    w0 = jnp.asarray([0.0, 1.0, 0.0])
    w_early = np.asarray(model.oja_pass(a, w0, 1.0, 5.0, 0.0))
    w_late = np.asarray(model.oja_pass(a, w0, 1.0, 5.0, 1000.0))
    # late pass has tiny steps: stays closer to w0
    assert abs(float(w_late @ np.asarray(w0))) > abs(float(w_early @ np.asarray(w0))) - 1e-9


def test_oja_pass_improves_alignment():
    a = _aniso_shard(400, 5, 9, scale0=4.0)
    w0 = jnp.asarray(np.ones(5) / np.sqrt(5.0))
    w = np.asarray(model.oja_pass(a, w0, 1.0, 20.0, 0.0))
    g = np.asarray(ref.gram(a))
    v1 = np.linalg.eigh(g)[1][:, -1]
    assert abs(w @ v1) > abs(np.asarray(w0) @ v1)


def test_entry_points_are_jittable():
    """AOT lowering requires all entries to trace under jit."""
    a = _shard(16, 3, 10)
    v = jnp.ones(3)
    jax.jit(model.cov_matvec)(a, v).block_until_ready()
    jax.jit(model.gram)(a).block_until_ready()
    jax.jit(model.local_top_eigvec)(a, v).block_until_ready()
    jax.jit(model.oja_pass)(a, v, 0.1, 1.0, 0.0).block_until_ready()
