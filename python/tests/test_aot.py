"""AOT pipeline: HLO text emission + manifest integrity."""

import json
import os

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np

from compile import aot, model


def test_to_hlo_text_emits_entry(tmp_path):
    a = jax.ShapeDtypeStruct((8, 3), jnp.float64)
    v = jax.ShapeDtypeStruct((3,), jnp.float64)
    text = aot.to_hlo_text(model.cov_matvec, (a, v))
    assert "ENTRY" in text
    assert "f64" in text


def test_build_writes_all_artifacts(tmp_path):
    out = str(tmp_path / "artifacts")
    manifest = aot.build(out, [(16, 4)])
    assert len(manifest["entries"]) == 4  # cov_matvec, gram, eig, oja
    for e in manifest["entries"]:
        path = os.path.join(out, e["file"])
        assert os.path.exists(path), e["file"]
        with open(path) as f:
            head = f.read(4096)
        assert "HloModule" in head
    # manifest readable back
    with open(os.path.join(out, "manifest.json")) as f:
        loaded = json.load(f)
    assert loaded["dtype"] == "f64"
    assert loaded["version"] == 1


def test_parse_shapes():
    assert aot.parse_shapes("400x64,200x32") == [(400, 64), (200, 32)]
    assert aot.parse_shapes(" 8X2 ") == [(8, 2)]
    assert aot.parse_shapes("") == []


def test_entry_points_shapes_consistent():
    eps = aot.entry_points(32, 8)
    names = [e[0] for e in eps]
    assert names == ["cov_matvec", "gram", "local_top_eigvec", "oja_pass"]
    for _, _, args, in_shapes, out_shapes in eps:
        assert len(args) == len(in_shapes)
        assert len(out_shapes) == 1


def test_lowered_hlo_is_runnable_by_jax(tmp_path):
    """Round-trip sanity: the lowered computation still computes the right
    numbers when executed by jax itself (the Rust-side execution is
    covered by the runtime integration tests)."""
    n, d = 12, 3
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((n, d)))
    v = jnp.asarray(rng.standard_normal(d))
    fitted = jax.jit(model.cov_matvec)
    got = fitted(a, v)
    want = (np.asarray(a).T @ (np.asarray(a) @ np.asarray(v))) / n
    np.testing.assert_allclose(got, want, rtol=1e-12)
