"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shard shapes and dtypes (the system's core correctness
signal); fixed-seed numpy cases pin the exact numerics.
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import cov_matvec, gram, ref

DIMS = st.tuples(st.integers(1, 70), st.integers(1, 24))
SEEDS = st.integers(0, 2**31 - 1)


def _shard(n, d, seed, dtype):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal((n, d)), dtype=dtype)


@settings(max_examples=40, deadline=None)
@given(dims=DIMS, seed=SEEDS)
def test_cov_matvec_matches_ref_f64(dims, seed):
    n, d = dims
    a = _shard(n, d, seed, jnp.float64)
    v = _shard(d, 1, seed + 1, jnp.float64)[:, 0]
    got = cov_matvec(a, v)
    want = ref.cov_matvec(a, v)
    np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-12)


@settings(max_examples=25, deadline=None)
@given(dims=DIMS, seed=SEEDS)
def test_cov_matvec_matches_ref_f32(dims, seed):
    n, d = dims
    a = _shard(n, d, seed, jnp.float32)
    v = _shard(d, 1, seed + 1, jnp.float32)[:, 0]
    got = cov_matvec(a, v)
    want = ref.cov_matvec(a, v)
    assert got.dtype == jnp.float32
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)


@settings(max_examples=40, deadline=None)
@given(dims=DIMS, seed=SEEDS)
def test_gram_matches_ref_f64(dims, seed):
    n, d = dims
    a = _shard(n, d, seed, jnp.float64)
    got = gram(a)
    want = ref.gram(a)
    np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-12)


@settings(max_examples=20, deadline=None)
@given(dims=DIMS, seed=SEEDS, blk=st.sampled_from([1, 3, 16, 128, 1024]))
def test_cov_matvec_block_size_invariance(dims, seed, blk):
    """The padded/tiled grid must be exact for every block size."""
    n, d = dims
    a = _shard(n, d, seed, jnp.float64)
    v = _shard(d, 1, seed + 2, jnp.float64)[:, 0]
    got = cov_matvec(a, v, block_n=blk)
    want = ref.cov_matvec(a, v)
    np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-12)


def test_gram_is_symmetric_psd():
    a = _shard(50, 8, 0, jnp.float64)
    g = np.asarray(gram(a))
    np.testing.assert_allclose(g, g.T, atol=1e-14)
    eigvals = np.linalg.eigvalsh(g)
    assert eigvals.min() > -1e-12


def test_cov_matvec_known_values():
    # A = [[1,0],[0,2]], v = (1,1): A^T A /n = diag(1,4)/2; result (0.5, 2)
    a = jnp.asarray([[1.0, 0.0], [0.0, 2.0]])
    v = jnp.asarray([1.0, 1.0])
    got = np.asarray(cov_matvec(a, v))
    np.testing.assert_allclose(got, [0.5, 2.0], atol=1e-15)


def test_single_row_shard():
    a = _shard(1, 5, 3, jnp.float64)
    v = _shard(5, 1, 4, jnp.float64)[:, 0]
    np.testing.assert_allclose(cov_matvec(a, v), ref.cov_matvec(a, v), rtol=1e-13)
    np.testing.assert_allclose(gram(a), ref.gram(a), rtol=1e-13)


def test_linear_in_v():
    a = _shard(30, 6, 5, jnp.float64)
    v1 = _shard(6, 1, 6, jnp.float64)[:, 0]
    v2 = _shard(6, 1, 7, jnp.float64)[:, 0]
    lhs = cov_matvec(a, 2.0 * v1 - 3.0 * v2)
    rhs = 2.0 * cov_matvec(a, v1) - 3.0 * cov_matvec(a, v2)
    np.testing.assert_allclose(lhs, rhs, rtol=1e-11, atol=1e-12)


def test_vmem_estimates_positive_and_monotonic():
    from compile.kernels.cov_matvec import vmem_estimate_bytes as cm_vmem
    from compile.kernels.gram import vmem_estimate_bytes as g_vmem

    assert cm_vmem(400, 64) > 0
    assert g_vmem(400, 64) > 0
    assert cm_vmem(400, 128) > cm_vmem(400, 64)
    # gram accumulator dominates at large d
    assert g_vmem(400, 512) > cm_vmem(400, 512)


@pytest.mark.parametrize("n,d", [(400, 64), (200, 32)])
def test_default_artifact_shapes_fit_vmem_budget(n, d):
    """The shapes we AOT must fit a 16 MB VMEM budget (f32 on real TPU)."""
    from compile.kernels.cov_matvec import vmem_estimate_bytes as cm_vmem
    from compile.kernels.gram import vmem_estimate_bytes as g_vmem

    assert cm_vmem(n, d) < 16 * 2**20
    assert g_vmem(n, d) < 16 * 2**20
